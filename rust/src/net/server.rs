//! Multi-node master: accepts n client connections and exposes them as a
//! [`ClientPool`], so `run_fednl_pool` / `run_fednl_ls_pool` drive real
//! distributed training unchanged (paper §9.3 setting: n clients + one
//! master, star topology, one TCP connection per client).

use std::net::TcpListener;

use anyhow::{Context, Result};

use super::framing::Channel;
use super::wire::{self, c2s, s2c};
use crate::algorithms::ClientMsg;
use crate::coordinator::ClientPool;

/// Master-side handle to n connected remote clients.
pub struct RemotePool {
    /// Channels indexed by registered client id.
    channels: Vec<Channel>,
    d: usize,
    alpha: f64,
}

/// A bound-but-not-yet-populated master socket; lets callers learn the
/// ephemeral port before spawning clients.
pub struct Bound {
    listener: TcpListener,
}

impl Bound {
    pub fn bind(addr: &str) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Self { listener })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept until exactly `n_clients` clients register.
    pub fn accept(self, n_clients: usize) -> Result<RemotePool> {
        RemotePool::accept_on(self.listener, n_clients)
    }
}

impl RemotePool {
    /// Listen on `addr` until exactly `n_clients` clients register.
    /// Clients may connect in any order; they self-identify with their
    /// id (dataset shard index).
    pub fn listen(addr: &str, n_clients: usize) -> Result<Self> {
        Bound::bind(addr)?.accept(n_clients)
    }

    fn accept_on(listener: TcpListener, n_clients: usize) -> Result<Self> {
        let mut slots: Vec<Option<Channel>> =
            (0..n_clients).map(|_| None).collect();
        let mut d = 0usize;
        let mut registered = 0;
        while registered < n_clients {
            let (stream, _) = listener.accept()?;
            let mut ch = Channel::new(stream)?;
            let (tag, payload) = ch.recv()?;
            anyhow::ensure!(tag == c2s::REGISTER, "expected REGISTER");
            let (id, dim) = wire::decode_register(&payload)?;
            let id = id as usize;
            anyhow::ensure!(id < n_clients, "client id {id} out of range");
            anyhow::ensure!(slots[id].is_none(), "duplicate client id {id}");
            if d == 0 {
                d = dim as usize;
            } else {
                anyhow::ensure!(d == dim as usize, "dimension mismatch");
            }
            slots[id] = Some(ch);
            registered += 1;
        }
        let channels = slots.into_iter().map(|s| s.unwrap()).collect();
        Ok(Self { channels, d, alpha: 0.0 })
    }

    fn broadcast(&mut self, tag: u8, payload: &[u8]) -> Result<()> {
        for ch in &mut self.channels {
            ch.send(tag, payload)?;
        }
        Ok(())
    }

    /// Politely shut all clients down.
    pub fn shutdown(&mut self) {
        let _ = self.broadcast(s2c::SHUTDOWN, &[]);
    }
}

impl crate::algorithms::fednl_pp::PPTransport for RemotePool {
    fn n_clients(&self) -> usize {
        self.channels.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn default_alpha(&self) -> f64 {
        <Self as ClientPool>::default_alpha(self)
    }

    fn set_alpha(&mut self, a: f64) {
        <Self as ClientPool>::set_alpha(self, a)
    }

    fn pp_init(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.broadcast(s2c::PP_INIT, &[]).expect("pp_init broadcast");
        self.channels
            .iter_mut()
            .map(|ch| {
                let (tag, p) = ch.recv().expect("pp_init reply");
                assert_eq!(tag, c2s::PP_STATE);
                wire::decode_loss_grad(&p).expect("pp state")
            })
            .collect()
    }

    fn pp_round(
        &mut self,
        x: &[f64],
        round: u64,
        selected: &[u32],
    ) -> Vec<crate::algorithms::fednl_pp::PPMsg> {
        let payload = wire::encode_round(x, round, false);
        for &ci in selected {
            self.channels[ci as usize]
                .send(s2c::PP_ROUND, &payload)
                .expect("pp send");
        }
        selected
            .iter()
            .map(|&ci| {
                let (tag, p) =
                    self.channels[ci as usize].recv().expect("pp reply");
                assert_eq!(tag, c2s::PP_MSG);
                let (id, update, dl, dg) =
                    wire::decode_pp_msg(&p).expect("pp decode");
                crate::algorithms::fednl_pp::PPMsg {
                    client_id: id as usize,
                    update,
                    dl,
                    dg,
                }
            })
            .collect()
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        <Self as ClientPool>::loss_grad(self, x)
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        <Self as ClientPool>::transport_bytes(self)
    }
}

impl ClientPool for RemotePool {
    fn n_clients(&self) -> usize {
        self.channels.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn kind_name(&self) -> &'static str {
        "remote"
    }

    fn default_alpha(&self) -> f64 {
        // The master does not know the remote compressor class until it
        // asks; clients reply to SET_ALPHA(NaN) with their α via ACK
        // payload — handled in `set_alpha`. Default conservative 1.0.
        if self.alpha > 0.0 {
            self.alpha
        } else {
            1.0
        }
    }

    fn set_alpha(&mut self, alpha: f64) {
        let payload = wire::encode_scalar(alpha);
        for ch in &mut self.channels {
            ch.send(s2c::SET_ALPHA, &payload).expect("set_alpha send");
        }
        let mut resolved = alpha;
        for ch in &mut self.channels {
            let (tag, p) = ch.recv().expect("set_alpha ack");
            assert_eq!(tag, c2s::ACK);
            if let Ok(a) = wire::decode_scalar(&p) {
                resolved = a; // clients echo the α they actually use
            }
        }
        self.alpha = resolved;
    }

    fn round(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> Vec<ClientMsg> {
        let payload = wire::encode_round(x, round, need_loss);
        self.broadcast(s2c::ROUND, &payload).expect("round broadcast");
        // Collect replies; channel order == client id order, but clients
        // compute concurrently because all sends complete first.
        let mut msgs: Vec<ClientMsg> = self
            .channels
            .iter_mut()
            .map(|ch| {
                let (tag, p) = ch.recv().expect("round reply");
                assert_eq!(tag, c2s::MSG);
                wire::decode_client_msg(&p).expect("decode client msg")
            })
            .collect();
        msgs.sort_by_key(|m| m.client_id);
        msgs
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        let payload = wire::encode_vec(x);
        self.broadcast(s2c::EVAL_LOSS, &payload).expect("eval broadcast");
        let mut sum = 0.0;
        for ch in &mut self.channels {
            let (tag, p) = ch.recv().expect("eval reply");
            assert_eq!(tag, c2s::LOSS);
            sum += wire::decode_scalar(&p).expect("loss");
        }
        sum / self.channels.len() as f64
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let payload = wire::encode_vec(x);
        self.broadcast(s2c::LOSS_GRAD, &payload).expect("grad broadcast");
        let inv_n = 1.0 / self.channels.len() as f64;
        let mut loss = 0.0;
        let mut g = vec![0.0; x.len()];
        for ch in &mut self.channels {
            let (tag, p) = ch.recv().expect("grad reply");
            assert_eq!(tag, c2s::GRAD);
            let (l, gi) = wire::decode_loss_grad(&p).expect("grad decode");
            loss += l;
            crate::linalg::vector::axpy(inv_n, &gi, &mut g);
        }
        (loss * inv_n, g)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let payload = wire::encode_vec(x);
        self.broadcast(s2c::WARM_START, &payload).expect("warm broadcast");
        self.channels
            .iter_mut()
            .map(|ch| {
                let (tag, p) = ch.recv().expect("warm reply");
                assert_eq!(tag, c2s::WARM);
                wire::decode_vec(&p).expect("warm decode")
            })
            .collect()
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        let up = self.channels.iter().map(|c| c.bytes_received).sum();
        let down = self.channels.iter().map(|c| c.bytes_sent).sum();
        Some((up, down))
    }
}

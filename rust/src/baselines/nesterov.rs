//! Nesterov accelerated gradient with adaptive (backtracking) step and
//! function-value restart — the stronger first-order comparator.

use super::BaselineOptions;
use crate::coordinator::ClientPool;
use crate::linalg::vector;
use crate::metrics::{RoundRecord, Trace};
use crate::net::wire;
use crate::utils::Stopwatch;

/// Run Nesterov-AGD until ‖∇f‖ ≤ tol or the round budget runs out.
pub fn run_nesterov(
    pool: &mut dyn ClientPool,
    opts: &BaselineOptions,
    x0: Vec<f64>,
) -> Trace {
    let d = x0.len();
    let n = pool.n_clients() as u64;
    let mut x = x0.clone();
    let mut y = x0;
    let mut t: f64 = 1.0;
    // 1/L estimate maintained by backtracking on the smoothness bound.
    let mut step = 1.0;
    let mut trace = Trace::new("Nesterov");
    let sw = Stopwatch::start();
    let mut bytes_up = 0u64;
    let mut bytes_down = 0u64;
    let mut f_prev = f64::INFINITY;

    for round in 0..opts.max_rounds {
        let (f_y, g_y) = pool.loss_grad(&y);
        // Exact framed sizes (LOSS_GRAD command down, GRAD reply up).
        bytes_down += wire::vec_frame_bytes(d) * n;
        bytes_up += wire::scalar_vec_frame_bytes(d) * n;
        let gnorm = vector::norm2(&g_y);
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss: f_y,
            bytes_up,
            bytes_down,
            elapsed: sw.elapsed_secs(),
            // Baseline reductions are all-or-nothing: full rounds only.
            committed: n as u32,
            missing: 0,
            flagged: 0,
        });
        if gnorm <= opts.tol_grad {
            break;
        }
        // Backtrack on the descent lemma: f(y − s·g) ≤ f(y) − s/2 ‖g‖².
        let mut s = step * 1.5;
        let mut x_new = vec![0.0; d];
        let gsq = vector::norm2_sq(&g_y);
        let mut accepted = false;
        for _ in 0..60 {
            vector::add_scaled(&y, -s, &g_y, &mut x_new);
            let f_new = pool.eval_loss(&x_new);
            bytes_down += wire::vec_frame_bytes(d) * n;
            bytes_up += wire::scalar_frame_bytes() * n;
            if f_new <= f_y - 0.5 * s * gsq {
                accepted = true;
                // Function-value restart: if progress stalls, reset
                // momentum (O'Donoghue–Candès heuristic).
                if f_new > f_prev {
                    t = 1.0;
                }
                f_prev = f_new;
                break;
            }
            s *= 0.5;
        }
        if !accepted {
            break;
        }
        step = s;
        let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_new;
        // y ← x_new + β (x_new − x)
        for i in 0..d {
            y[i] = x_new[i] + beta * (x_new[i] - x[i]);
        }
        x = x_new;
        t = t_new;
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::gd::tests::pool;
    use crate::baselines::run_gd;

    #[test]
    fn nesterov_converges() {
        let (mut p, d) = pool(3, 51);
        let opts = BaselineOptions { max_rounds: 3000, tol_grad: 1e-6 };
        let tr = run_nesterov(&mut p, &opts, vec![0.0; d]);
        assert!(tr.last_grad_norm() <= 1e-6, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn nesterov_not_slower_than_gd() {
        let (mut p1, d) = pool(3, 52);
        let (mut p2, _) = pool(3, 52);
        let opts = BaselineOptions { max_rounds: 4000, tol_grad: 1e-7 };
        let tg = run_gd(&mut p1, &opts, vec![0.0; d]);
        let tn = run_nesterov(&mut p2, &opts, vec![0.0; d]);
        let rg = tg.rounds_to_tolerance(1e-7).unwrap_or(u64::MAX);
        let rn = tn.rounds_to_tolerance(1e-7).unwrap_or(u64::MAX);
        // Acceleration should not lose by more than a small factor.
        assert!(rn as f64 <= rg as f64 * 1.5, "nesterov {rn} vs gd {rg}");
    }
}

//! Quickstart: train L2-regularized logistic regression with FedNL
//! (TopK compression) on a synthetic dataset, in-process.
//!
//!     cargo run --release --example quickstart

use fednl::algorithms::{run_fednl, ClientState, Options};
use fednl::compressors::by_name;
use fednl::data::{generate_synthetic, Dataset, LibsvmSample, SynthSpec};
use fednl::oracle::LogisticOracle;

fn main() -> anyhow::Result<()> {
    // 1. A small synthetic classification problem (d = 64 features).
    let spec = SynthSpec::preset("quickstart").unwrap();
    let synth = generate_synthetic(&spec);
    let samples: Vec<LibsvmSample> = synth
        .labels
        .iter()
        .zip(&synth.rows)
        .map(|(l, r)| LibsvmSample { label: *l, features: r.clone() })
        .collect();
    let mut ds = Dataset::from_libsvm(&samples, spec.d_raw);
    ds.reshuffle(42);
    let d = ds.d;

    // 2. Split across 8 federated clients; each owns a private shard.
    let clients: Vec<ClientState> = ds
        .split_even(8)?
        .into_iter()
        .enumerate()
        .map(|(i, shard)| {
            ClientState::new(
                i,
                Box::new(LogisticOracle::new(shard, 1e-3)),
                by_name("topk", d, 8, 7 + i as u64).unwrap(),
                None, // theoretical α from the compressor class
            )
        })
        .collect();

    // 3. Run FedNL (Algorithm 1, Option 2) for 50 rounds.
    let opts = Options { rounds: 90, track_loss: true, ..Default::default() };
    let mut clients = clients;
    let trace = run_fednl(&mut clients, &opts, vec![0.0; d]);

    // 4. Superlinear convergence: the grad norm collapses within dozens
    //    of rounds while only k = 8d of d(d+1)/2 Hessian entries move
    //    per client per round.
    println!("round  ||grad||      f(x)");
    for r in trace.records.iter().step_by(5) {
        println!("{:>5}  {:<12.3e}  {:.6}", r.round, r.grad_norm, r.loss);
    }
    println!(
        "\nfinal ||grad|| = {:.3e} after {} rounds, {} uploaded",
        trace.last_grad_norm(),
        trace.records.len(),
        fednl::utils::human_bytes(trace.total_bytes_up())
    );
    assert!(trace.last_grad_norm() < 1e-8);
    Ok(())
}

//! Micro-benchmarks of the hot kernels (harness = false; self-contained
//! criterion-style statistics via `fednl::utils::TimerStats`).
//!
//! Run: `cargo bench --bench microbench [-- filter] [--bench-json]`
//!
//! The `kernels` section A/Bs every runtime-dispatched SIMD kernel
//! against its portable scalar fallback, plus an `avx512_ns` column
//! pinning each kernel to the AVX-512 tier where the host and the
//! toolchain provide it (JSON `null` otherwise); with `--bench-json`
//! the per-kernel timings are written to `BENCH_kernels.json` (see
//! ROADMAP.md for the schema) so the perf trajectory is tracked across
//! PRs. The `coordinator` and `shard` sections emit
//! `BENCH_coordinator.json` / `BENCH_shard.json` the same way (the
//! master's wait-vs-aggregate wall-clock split, flat and through the
//! sharded aggregation tier, with per-round shard→master
//! `payload_bytes`; the coordinator section adds a deterministic
//! straggler A/B of `--speculate` with an `overlap_s` column); the
//! `reduce` section emits `BENCH_reduce.json` (exact RepAcc
//! superaccumulation vs naive f64 folding, scalar vs the dispatched
//! SIMD kernel, plus the pinned AVX-512 limb scatter).

use fednl::compressors::{by_name, ALL_NAMES};
use fednl::data::ClientShard;
use fednl::linalg::packed::PackedUpper;
use fednl::linalg::{cholesky, gauss, iterative, simd, Mat};
use fednl::oracle::{LogisticOracle, Oracle};
use fednl::rng::{Pcg64, Rng};
use fednl::utils::TimerStats;

fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) {
    for _ in 0..warmup {
        f();
    }
    let mut st = TimerStats::new();
    for _ in 0..iters {
        st.time(&mut f);
    }
    println!(
        "{name:<46} min {:>10.3?}µs  median {:>10.3?}µs  mean {:>10.3?}µs ±{:>8.3?}",
        st.min() * 1e6,
        st.median() * 1e6,
        st.mean() * 1e6,
        st.stddev() * 1e6
    );
}

/// Minimum-of-samples timing (paper App. G.3 protocol) in seconds.
fn time_min<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut st = TimerStats::new();
    for _ in 0..iters {
        st.time(&mut f);
    }
    st.min()
}

/// One scalar-vs-dispatched A/B row for `BENCH_kernels.json`. The
/// `avx512_ns` column pins the kernel to the AVX-512 tier via the
/// `*_on` wrappers; it is `None` (JSON `null`) when the host or the
/// toolchain lacks the tier, and for rows where a pinned tier makes no
/// sense (the multithreaded row).
struct KernelRow {
    name: &'static str,
    n: usize,
    scalar_ns: f64,
    simd_ns: f64,
    avx512_ns: Option<f64>,
}

impl KernelRow {
    fn speedup(&self) -> f64 {
        if self.simd_ns > 0.0 {
            self.scalar_ns / self.simd_ns
        } else {
            0.0
        }
    }
}

/// `Option<f64>` → JSON number or `null` (hand-rolled writer).
fn json_opt_ns(v: Option<f64>) -> String {
    match v {
        Some(ns) => format!("{ns:.1}"),
        None => "null".into(),
    }
}

/// A/B every dispatched kernel against its scalar fallback.
fn bench_kernels() -> Vec<KernelRow> {
    let mut rng = Pcg64::seed_from_u64(0xBE_AC_11);
    let mut rows = Vec::new();
    let d = 301; // W8A shape
    let pu = PackedUpper::new(d);
    let n_packed = pu.len();
    let has512 = simd::isa_available(simd::Isa::Avx512);

    // dot / norm2_sq (margin-length and packed-length vectors).
    for &n in &[d, 4096] {
        let a: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let scalar_ns = time_min(50, 400, || {
            std::hint::black_box(simd::scalar::dot(
                std::hint::black_box(&a),
                std::hint::black_box(&b),
            ));
        }) * 1e9;
        let simd_ns = time_min(50, 400, || {
            std::hint::black_box(simd::dot(
                std::hint::black_box(&a),
                std::hint::black_box(&b),
            ));
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(50, 400, || {
                std::hint::black_box(simd::dot_on(
                    simd::Isa::Avx512,
                    std::hint::black_box(&a),
                    std::hint::black_box(&b),
                ));
            }) * 1e9
        });
        rows.push(KernelRow { name: "dot", n, scalar_ns, simd_ns, avx512_ns });
    }

    // axpy (gradient accumulation sweep length).
    {
        let n = 4096;
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut y1: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut y2 = y1.clone();
        let mut y3 = y1.clone();
        let scalar_ns = time_min(50, 400, || {
            simd::scalar::axpy(1.000000001, std::hint::black_box(&x), &mut y1);
        }) * 1e9;
        let simd_ns = time_min(50, 400, || {
            simd::axpy(1.000000001, std::hint::black_box(&x), &mut y2);
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(50, 400, || {
                simd::axpy_on(
                    simd::Isa::Avx512,
                    1.000000001,
                    std::hint::black_box(&x),
                    &mut y3,
                );
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "axpy",
            n,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });
    }

    // §5.10 rank-1 Hessian accumulate (the hottest FedNL kernel).
    {
        let n_i = 64;
        let samples: Vec<Vec<f64>> = (0..n_i)
            .map(|_| (0..d).map(|_| rng.next_gaussian()).collect())
            .collect();
        let refs: Vec<&[f64]> = samples.iter().map(|s| s.as_slice()).collect();
        let h: Vec<f64> = (0..n_i).map(|_| rng.next_f64() + 0.1).collect();
        let mut m = vec![0.0; d * d];
        let scalar_ns = time_min(3, 30, || {
            simd::scalar::sym_rank1_upper(&mut m, d, &refs, &h);
        }) * 1e9;
        let simd_ns = time_min(3, 30, || {
            simd::sym_rank1_upper(&mut m, d, &refs, &h);
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(3, 30, || {
                simd::sym_rank1_upper_on(
                    simd::Isa::Avx512,
                    &mut m,
                    d,
                    &refs,
                    &h,
                );
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "sym_rank1_upper",
            n: d * n_i,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });
    }

    // §5.10 accumulate threaded across samples *within* one client
    // (ROADMAP perf item): 1 thread vs all cores, bit-identical
    // results. In this row "scalar_ns" = single-threaded dispatched
    // kernel, "simd_ns" = row-block threaded kernel.
    {
        let n_i = 256;
        let samples: Vec<Vec<f64>> = (0..n_i)
            .map(|_| (0..d).map(|_| rng.next_gaussian()).collect())
            .collect();
        let refs: Vec<&[f64]> = samples.iter().map(|s| s.as_slice()).collect();
        let h: Vec<f64> = (0..n_i).map(|_| rng.next_f64() + 0.1).collect();
        let cores = fednl::utils::available_cores();
        let mut m = vec![0.0; d * d];
        let scalar_ns = time_min(2, 20, || {
            simd::sym_rank1_upper_threaded(&mut m, d, &refs, &h, 1);
        }) * 1e9;
        let simd_ns = time_min(2, 20, || {
            simd::sym_rank1_upper_threaded(&mut m, d, &refs, &h, cores);
        }) * 1e9;
        rows.push(KernelRow {
            name: "sym_rank1_upper_mt",
            n: d * n_i,
            scalar_ns,
            simd_ns,
            // The threaded row A/Bs 1 core vs all cores on the
            // *dispatched* kernel; a pinned tier is a different axis.
            avx512_ns: None,
        });
    }

    // Compressor scans over the packed upper triangle.
    {
        let v: Vec<f64> = (0..n_packed).map(|_| rng.next_gaussian()).collect();
        let mut e = vec![0.0; n_packed];
        let scalar_ns = time_min(20, 200, || {
            simd::scalar::energy_scan(pu.weights(), std::hint::black_box(&v), &mut e);
        }) * 1e9;
        let simd_ns = time_min(20, 200, || {
            simd::energy_scan(pu.weights(), std::hint::black_box(&v), &mut e);
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(20, 200, || {
                simd::energy_scan_on(
                    simd::Isa::Avx512,
                    pu.weights(),
                    std::hint::black_box(&v),
                    &mut e,
                );
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "energy_scan",
            n: n_packed,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });

        let scalar_ns = time_min(20, 200, || {
            std::hint::black_box(simd::scalar::weighted_norm2_sq(
                pu.weights(),
                std::hint::black_box(&v),
            ));
        }) * 1e9;
        let simd_ns = time_min(20, 200, || {
            std::hint::black_box(simd::weighted_norm2_sq(
                pu.weights(),
                std::hint::black_box(&v),
            ));
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(20, 200, || {
                std::hint::black_box(simd::weighted_norm2_sq_on(
                    simd::Isa::Avx512,
                    pu.weights(),
                    std::hint::black_box(&v),
                ));
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "weighted_norm2_sq",
            n: n_packed,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });

        let scalar_ns = time_min(20, 200, || {
            std::hint::black_box(simd::scalar::abs_max(std::hint::black_box(&v)));
        }) * 1e9;
        let simd_ns = time_min(20, 200, || {
            std::hint::black_box(simd::abs_max(std::hint::black_box(&v)));
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(20, 200, || {
                std::hint::black_box(simd::abs_max_on(
                    simd::Isa::Avx512,
                    std::hint::black_box(&v),
                ));
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "abs_max",
            n: n_packed,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });
    }

    // §5.7 sigmoid-variance weight scan.
    {
        let n = 4096;
        let s: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut out = vec![0.0; n];
        let scalar_ns = time_min(50, 400, || {
            simd::scalar::sigmoid_variance_scan(std::hint::black_box(&s), 0.01, &mut out);
        }) * 1e9;
        let simd_ns = time_min(50, 400, || {
            simd::sigmoid_variance_scan(std::hint::black_box(&s), 0.01, &mut out);
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(50, 400, || {
                simd::sigmoid_variance_scan_on(
                    simd::Isa::Avx512,
                    std::hint::black_box(&s),
                    0.01,
                    &mut out,
                );
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "sigmoid_variance_scan",
            n,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });
    }

    // Fused margin→σ(-z) scan. The "scalar" baseline is the libm-exp
    // path the vectorized polynomial replaced (what `FEDNL_EXACT_EXP=1`
    // restores), so the row meters the exp→poly win end to end.
    {
        let n = 4096;
        let z: Vec<f64> =
            (0..n).map(|_| rng.next_gaussian() * 12.0).collect();
        let mut out = vec![0.0; n];
        let scalar_ns = time_min(50, 400, || {
            let z = std::hint::black_box(&z);
            for (o, &zi) in out.iter_mut().zip(z.iter()) {
                *o = simd::sigmoid_exact(-zi);
            }
        }) * 1e9;
        let simd_ns = time_min(50, 400, || {
            simd::sigmoid_neg_scan(std::hint::black_box(&z), &mut out);
        }) * 1e9;
        let avx512_ns = has512.then(|| {
            time_min(50, 400, || {
                simd::sigmoid_neg_scan_on(
                    simd::Isa::Avx512,
                    std::hint::black_box(&z),
                    &mut out,
                );
            }) * 1e9
        });
        rows.push(KernelRow {
            name: "sigmoid_neg_scan",
            n,
            scalar_ns,
            simd_ns,
            avx512_ns,
        });
    }

    for r in &rows {
        let a512 = match r.avx512_ns {
            Some(ns) => format!("{ns:>9.1}ns"),
            None => format!("{:>11}", "-"),
        };
        println!(
            "kernel/{:<24} n={:<6} scalar {:>9.1}ns  simd {:>9.1}ns  avx512 {a512}  ×{:.2}",
            r.name,
            r.n,
            r.scalar_ns,
            r.simd_ns,
            r.speedup()
        );
    }
    rows
}

/// Serialize the kernel A/B rows to `BENCH_kernels.json` (schema in
/// ROADMAP.md; hand-rolled writer — the crate stays dependency-free).
fn write_bench_json(rows: &[KernelRow]) -> std::io::Result<()> {
    let mut s = String::from("{\n");
    s.push_str(&format!("  \"isa\": \"{}\",\n", simd::isa_name()));
    s.push_str(&format!(
        "  \"cores\": {},\n",
        fednl::utils::available_cores()
    ));
    s.push_str("  \"kernels\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"n\": {}, \"scalar_ns\": {:.1}, \"simd_ns\": {:.1}, \"avx512_ns\": {}, \"speedup\": {:.3}}}{}\n",
            r.name,
            r.n,
            r.scalar_ns,
            r.simd_ns,
            json_opt_ns(r.avx512_ns),
            r.speedup(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write("BENCH_kernels.json", s)?;
    println!("kernel timings written to BENCH_kernels.json");
    Ok(())
}

fn random_shard(d: usize, n: usize, seed: u64) -> ClientShard {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut at = Mat::zeros(n, d);
    for r in 0..n {
        let lab = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        for c in 0..d - 1 {
            at.set(r, c, lab * rng.next_gaussian());
        }
        at.set(r, d - 1, lab);
    }
    ClientShard { client_id: 0, at }
}

fn random_spd(d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let b = Mat::from_vec(d, d, (0..d * d).map(|_| rng.next_gaussian()).collect());
    let mut a = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            let mut s = 0.0;
            for k in 0..d {
                s += b.get(k, i) * b.get(k, j);
            }
            a.set(i, j, s / d as f64);
        }
    }
    a.add_diag(1.0);
    a
}

fn main() {
    // cargo bench appends `--bench`; ignore flag-like args.
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let json = std::env::args().any(|a| a == "--bench-json");
    let want = |n: &str| filter.is_empty() || n.contains(&filter);
    println!("== microbench (W8A client shape d=301, n_i=350) ==");
    println!("dispatched SIMD path: {}", simd::isa_name());

    let d = 301;
    let n_i = 350;
    let shard = random_shard(d, n_i, 1);

    if want("kernels") {
        let rows = bench_kernels();
        if json {
            if let Err(e) = write_bench_json(&rows) {
                eprintln!("failed to write BENCH_kernels.json: {e}");
            }
        }
    }

    if want("reduce") {
        // Reproducible-summation layer: RepAcc superaccumulation vs a
        // naive f64 fold, scalar vs the dispatched (AVX2-assisted)
        // bulk kernel. The accumulator is exact, so the interesting
        // number is the slowdown paid for exactness — emitted as
        // BENCH_reduce.json and gated on simd_ns by check_bench.py.
        use fednl::linalg::reduce::{RepAcc, LIMBS};

        struct ReduceRow {
            name: &'static str,
            n: usize,
            naive_ns: f64,
            scalar_ns: f64,
            simd_ns: f64,
            /// Raw limb-scatter kernel pinned to the AVX-512 tier
            /// (`None` when the tier is unavailable / inapplicable).
            avx512_ns: Option<f64>,
        }
        let has512 = simd::isa_available(simd::Isa::Avx512);
        let mut rng = Pcg64::seed_from_u64(0x5ED_0CE);
        let mut rows = Vec::new();
        for &n in &[301usize, 4096] {
            let xs: Vec<f64> =
                (0..n).map(|_| rng.next_gaussian()).collect();
            let naive_ns = time_min(50, 400, || {
                // The fold RepAcc replaces: 4-way unrolled f64 sum.
                let chunks = xs.len() / 4;
                let (mut s0, mut s1, mut s2, mut s3) =
                    (0.0f64, 0.0, 0.0, 0.0);
                for c in 0..chunks {
                    let i = c * 4;
                    s0 += xs[i];
                    s1 += xs[i + 1];
                    s2 += xs[i + 2];
                    s3 += xs[i + 3];
                }
                let mut s = (s0 + s1) + (s2 + s3);
                for &v in &xs[chunks * 4..] {
                    s += v;
                }
                std::hint::black_box(s);
            }) * 1e9;
            let mut acc = RepAcc::new();
            let scalar_ns = time_min(20, 200, || {
                acc.reset();
                acc.accumulate_slice_scalar(std::hint::black_box(&xs));
                std::hint::black_box(&acc);
            }) * 1e9;
            let simd_ns = time_min(20, 200, || {
                acc.reset();
                acc.accumulate_slice(std::hint::black_box(&xs));
                std::hint::black_box(&acc);
            }) * 1e9;
            let avx512_ns = has512.then(|| {
                let mut limbs = [0i64; LIMBS];
                time_min(20, 200, || {
                    limbs = [0i64; LIMBS];
                    std::hint::black_box(simd::binned_accumulate_on(
                        simd::Isa::Avx512,
                        &mut limbs,
                        std::hint::black_box(&xs),
                    ));
                }) * 1e9
            });
            rows.push(ReduceRow {
                name: "binned_accumulate",
                n,
                naive_ns,
                scalar_ns,
                simd_ns,
                avx512_ns,
            });
        }
        // Shard-tier merge: S partial sums folded at the master — the
        // per-round aggregate cost the pre-reduction leaves behind.
        {
            let n = 4096;
            let xs: Vec<f64> =
                (0..n).map(|_| rng.next_gaussian()).collect();
            let mut parts: Vec<RepAcc> = (0..4)
                .map(|s| {
                    let mut a = RepAcc::new();
                    a.accumulate_slice(&xs[s * n / 4..(s + 1) * n / 4]);
                    a
                })
                .collect();
            let naive_ns = time_min(200, 2000, || {
                let mut s = 0.0f64;
                for p in parts.iter() {
                    s += std::hint::black_box(p.clone()).round();
                }
                std::hint::black_box(s);
            }) * 1e9;
            let mut acc = RepAcc::new();
            let merge_ns = time_min(200, 2000, || {
                acc.reset();
                for p in parts.iter_mut() {
                    acc.merge(p.clone());
                }
                std::hint::black_box(acc.round());
            }) * 1e9;
            rows.push(ReduceRow {
                name: "repacc_merge4",
                n,
                naive_ns,
                scalar_ns: merge_ns,
                simd_ns: merge_ns,
                // Merging limb arrays is ISA-independent bookkeeping.
                avx512_ns: None,
            });
        }
        for r in &rows {
            let a512 = match r.avx512_ns {
                Some(ns) => format!("{ns:>9.1}ns"),
                None => format!("{:>11}", "-"),
            };
            println!(
                "reduce/{:<20} n={:<6} naive {:>9.1}ns  scalar {:>9.1}ns  simd {:>9.1}ns  avx512 {a512}  exactness x{:.2}",
                r.name,
                r.n,
                r.naive_ns,
                r.scalar_ns,
                r.simd_ns,
                if r.naive_ns > 0.0 { r.simd_ns / r.naive_ns } else { 0.0 }
            );
        }
        if json {
            let mut s = String::from("{\n");
            s.push_str(&format!(
                "  \"isa\": \"{}\",\n  \"cores\": {},\n",
                simd::isa_name(),
                fednl::utils::available_cores()
            ));
            s.push_str("  \"reduce\": [\n");
            for (i, r) in rows.iter().enumerate() {
                s.push_str(&format!(
                    "    {{\"name\": \"{}\", \"n\": {}, \"naive_ns\": {:.1}, \"scalar_ns\": {:.1}, \"simd_ns\": {:.1}, \"avx512_ns\": {}}}{}\n",
                    r.name,
                    r.n,
                    r.naive_ns,
                    r.scalar_ns,
                    r.simd_ns,
                    json_opt_ns(r.avx512_ns),
                    if i + 1 < rows.len() { "," } else { "" }
                ));
            }
            s.push_str("  ]\n}\n");
            match std::fs::write("BENCH_reduce.json", s) {
                Ok(()) => {
                    println!("reduce timings written to BENCH_reduce.json")
                }
                Err(e) => {
                    eprintln!("failed to write BENCH_reduce.json: {e}")
                }
            }
        }
    }

    if want("coordinator") {
        // Streaming-pool wait vs aggregate wall-clock split: how much
        // of a FedNL run the master spends blocked on `drain()` vs
        // committing replies (buffer-and-commit), plus the speculative
        // A/B — a deterministic straggler schedule (one over-deadline
        // client per round, quorum n−1) run with and without
        // `--speculate`. Speculation overlaps the server-side round
        // finish with the straggler-detection wait, so the "+spec" row
        // shows the same wait but a lower total and a nonzero
        // `overlap_s`; both trajectories are bit-identical (asserted).
        // Emitted as BENCH_coordinator.json with --bench-json.
        use fednl::algorithms::{
            run_fednl_pool, ClientState, Options, RoundPolicy,
        };
        use fednl::coordinator::{
            ClientPool, FaultPlan, FaultPool, SeqPool, ThreadedPool,
        };

        let n_clients = 8;
        let dd = 61;
        let rounds = 40u64;
        let make = || -> Vec<ClientState> {
            (0..n_clients)
                .map(|i| {
                    let sh = random_shard(dd, 80, 100 + i as u64);
                    ClientState::new(
                        i,
                        Box::new(LogisticOracle::new(sh, 1e-3)),
                        by_name("topk", dd, 8, 500 + i as u64).unwrap(),
                        None,
                    )
                })
                .collect()
        };
        let opts = Options { rounds, track_loss: true, ..Default::default() };
        struct CoordRun {
            pool: String,
            wait_s: f64,
            aggregate_s: f64,
            overlap_s: f64,
            total_s: f64,
            /// Steady-state server-side bookkeeping per registered
            /// client (the event-transport scaling row only).
            idle_client_bytes: Option<f64>,
        }
        let mut results: Vec<CoordRun> = Vec::new();
        {
            let mut pool = SeqPool::new(make());
            let tr = run_fednl_pool(&mut pool, &opts, vec![0.0; dd], "coord/seq");
            results.push(CoordRun {
                pool: pool.kind_name().to_string(),
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                overlap_s: tr.overlap_secs,
                total_s: tr.total_elapsed(),
                idle_client_bytes: None,
            });
        }
        {
            let mut pool = ThreadedPool::new(make(), 0);
            let tr =
                run_fednl_pool(&mut pool, &opts, vec![0.0; dd], "coord/thr");
            results.push(CoordRun {
                pool: pool.kind_name().to_string(),
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                overlap_s: tr.overlap_secs,
                total_s: tr.total_elapsed(),
                idle_client_bytes: None,
            });
        }
        // Speculative A/B. Larger d so the overlapped server work
        // (Hessian finish + Newton solve) is substantial; a short
        // reply deadline so the per-round straggler window stays
        // cheap. Client 7 exceeds the deadline every round, so each
        // round closes on exactly the quorum-7 snapshot and every
        // speculation is adopted.
        let dd_f = 256;
        let rounds_f = 12u64;
        let deadline_ms = 30u64;
        let make_f = || -> Vec<ClientState> {
            (0..n_clients)
                .map(|i| {
                    let sh = random_shard(dd_f, 80, 900 + i as u64);
                    ClientState::new(
                        i,
                        Box::new(LogisticOracle::new(sh, 1e-3)),
                        by_name("topk", dd_f, 8, 1300 + i as u64).unwrap(),
                        None,
                    )
                })
                .collect()
        };
        let mut plan = FaultPlan::default();
        for r in 0..=rounds_f {
            plan = plan.with_delay(r, n_clients as u32 - 1, 1000);
        }
        let policy = RoundPolicy {
            quorum: Some(n_clients - 1),
            deadline_ms: Some(deadline_ms),
            ..Default::default()
        };
        let mut grad_bits = Vec::new();
        for speculate in [false, true] {
            let opts_f = Options {
                rounds: rounds_f,
                track_loss: true,
                policy,
                speculate,
                ..Default::default()
            };
            let mut pool =
                FaultPool::new(ThreadedPool::new(make_f(), 0), plan.clone());
            let label =
                if speculate { "coord/faulty+spec" } else { "coord/faulty" };
            let tr =
                run_fednl_pool(&mut pool, &opts_f, vec![0.0; dd_f], label);
            grad_bits.push(tr.last_grad_norm().to_bits());
            results.push(CoordRun {
                pool: if speculate {
                    "faulty+spec".to_string()
                } else {
                    "faulty".to_string()
                },
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                overlap_s: tr.overlap_secs,
                total_s: tr.total_elapsed(),
                idle_client_bytes: None,
            });
        }
        assert_eq!(
            grad_bits[0], grad_bits[1],
            "speculative trajectory diverged from the inline path"
        );
        // Defense-overhead A/B (`--defense median` vs off) on the same
        // clean problem as the seq/threaded rows: enabling a robust
        // fold pays for the atom round path (per-client commits
        // instead of pre-reduced sums) plus the coordinate-wise
        // total_cmp sort at the master. Both rows are gated generously
        // by ci/check_bench.py so a pathological fold regression fails
        // the bench job.
        for defense in [None, Some(fednl::robust::Defense::Median)] {
            let opts_d = Options {
                rounds,
                track_loss: true,
                defense,
                ..Default::default()
            };
            let (label, row) = if defense.is_some() {
                ("coord/defense", "defense/median")
            } else {
                ("coord/nodefense", "defense/off")
            };
            let mut pool = ThreadedPool::new(make(), 0);
            let tr = run_fednl_pool(&mut pool, &opts_d, vec![0.0; dd], label);
            results.push(CoordRun {
                pool: row.to_string(),
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                overlap_s: tr.overlap_secs,
                total_s: tr.total_elapsed(),
                idle_client_bytes: None,
            });
        }
        // Readiness-transport scaling row: 100k multiplexed clients
        // over 16 loopback group sockets through one EventPool master
        // (tiny per-client problem — the measured quantity is the
        // transport: registration, two full rounds, and the idle
        // per-client bookkeeping gated by ci/check_bench.py).
        #[cfg(unix)]
        {
            use fednl::net::server::Bound;
            use fednl::net::{run_mux_clients, EventPool};
            let n_big = 100_000usize;
            let groups = 16usize;
            let d_big = 6usize;
            let per = n_big / groups;
            let bound = Bound::bind("127.0.0.1:0").unwrap();
            let addr = bound.local_addr().unwrap().to_string();
            let mut handles = Vec::new();
            for g in 0..groups {
                let addr = addr.clone();
                handles.push(std::thread::spawn(move || {
                    let mut clients: Vec<ClientState> = (g * per
                        ..(g + 1) * per)
                        .map(|i| {
                            let sh = random_shard(d_big, 2, 3000 + i as u64);
                            ClientState::new(
                                i,
                                Box::new(LogisticOracle::new(sh, 1e-3)),
                                by_name("topk", d_big, 8, 7000 + i as u64)
                                    .unwrap(),
                                None,
                            )
                        })
                        .collect();
                    run_mux_clients(&mut clients, g as u32, &addr).unwrap();
                }));
            }
            let mut pool = EventPool::accept(bound, n_big).unwrap();
            let opts_big = Options { rounds: 2, ..Default::default() };
            let tr = run_fednl_pool(
                &mut pool,
                &opts_big,
                vec![0.0; d_big],
                "coord/event100k",
            );
            let idle = pool.idle_bytes_per_client();
            pool.shutdown();
            for h in handles {
                h.join().unwrap();
            }
            assert!(
                tr.records.iter().all(|r| r.committed as usize == n_big),
                "event100k: rounds incomplete"
            );
            results.push(CoordRun {
                pool: "event100k".to_string(),
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                overlap_s: tr.overlap_secs,
                total_s: tr.total_elapsed(),
                idle_client_bytes: Some(idle),
            });
        }
        for r in &results {
            let idle = r
                .idle_client_bytes
                .map(|b| format!("  idle {b:>7.1} B/client"))
                .unwrap_or_default();
            println!(
                "coordinator/{:<12} wait {:>9.3}ms  aggregate {:>9.3}ms  overlap {:>9.3}ms  total {:>9.3}ms{idle}",
                r.pool,
                r.wait_s * 1e3,
                r.aggregate_s * 1e3,
                r.overlap_s * 1e3,
                r.total_s * 1e3
            );
        }
        if json {
            let mut s = String::from("{\n");
            s.push_str(&format!(
                "  \"rounds\": {rounds}, \"n_clients\": {n_clients}, \"d\": {dd}, \"faulty_rounds\": {rounds_f}, \"faulty_d\": {dd_f}, \"cores\": {},\n",
                fednl::utils::available_cores()
            ));
            s.push_str("  \"pools\": [\n");
            for (i, r) in results.iter().enumerate() {
                let idle = r
                    .idle_client_bytes
                    .map(|b| format!(", \"idle_client_bytes\": {b:.1}"))
                    .unwrap_or_default();
                s.push_str(&format!(
                    "    {{\"pool\": \"{}\", \"wait_s\": {:.6}, \"aggregate_s\": {:.6}, \"overlap_s\": {:.6}, \"total_s\": {:.6}{}}}{}\n",
                    r.pool,
                    r.wait_s,
                    r.aggregate_s,
                    r.overlap_s,
                    r.total_s,
                    idle,
                    if i + 1 < results.len() { "," } else { "" }
                ));
            }
            s.push_str("  ]\n}\n");
            match std::fs::write("BENCH_coordinator.json", s) {
                Ok(()) => println!(
                    "coordinator timings written to BENCH_coordinator.json"
                ),
                Err(e) => {
                    eprintln!("failed to write BENCH_coordinator.json: {e}")
                }
            }
        }
    }

    if want("shard") {
        // Sharded aggregation tier: wall-clock split of the same FedNL
        // run at S=1 (flat) vs sharded S∈{2,3}, plus the per-shard
        // wait/aggregate attribution. Emitted as BENCH_shard.json with
        // --bench-json; `ci/check_bench.py` gates each config's
        // total_s. Trajectories are bit-identical across configs (the
        // tier's determinism invariant — asserted by the integration
        // tests, spot-checked here).
        use fednl::algorithms::{run_fednl_pool, ClientState, Options};
        use fednl::coordinator::{
            ClientPool, SeqPool, ShardedPool, ShardStats,
        };

        let n_clients = 12;
        let dd = 41;
        let rounds = 30u64;
        let make_n = |n: usize| -> Vec<ClientState> {
            (0..n)
                .map(|i| {
                    let sh = random_shard(dd, 60, 300 + i as u64);
                    ClientState::new(
                        i,
                        Box::new(LogisticOracle::new(sh, 1e-3)),
                        by_name("topk", dd, 8, 700 + i as u64).unwrap(),
                        None,
                    )
                })
                .collect()
        };
        let make = || make_n(n_clients);
        let opts = Options { rounds, track_loss: true, ..Default::default() };
        struct ShardRun {
            key: String,
            shards: usize,
            wait_s: f64,
            aggregate_s: f64,
            total_s: f64,
            /// Shard→master payload per round: SHARD_SUM frames for
            /// S>1, the per-client atom bytes for the flat S=1 run.
            payload_bytes: u64,
            final_grad: f64,
            per_shard: Vec<ShardStats>,
        }
        let mut runs: Vec<ShardRun> = Vec::new();
        {
            let mut pool = SeqPool::new(make());
            let tr =
                run_fednl_pool(&mut pool, &opts, vec![0.0; dd], "shard/S1");
            runs.push(ShardRun {
                key: "S=1/seq".into(),
                shards: 1,
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                total_s: tr.total_elapsed(),
                // Exactly the per-round MSG atom bytes: a FedNL run
                // without warm start has no other upward traffic in
                // the logical counters, so total/rounds is the clean
                // flat-path counterpart of the SHARD_SUM frames the
                // S>1 configs meter.
                payload_bytes: tr.total_bytes_up() / rounds,
                final_grad: tr.last_grad_norm(),
                per_shard: Vec::new(),
            });
        }
        for s in [2usize, 3] {
            let mut pool = ShardedPool::new_seq(make(), s);
            let tr = run_fednl_pool(
                &mut pool,
                &opts,
                vec![0.0; dd],
                &format!("shard/S{s}"),
            );
            let payload: u64 = pool
                .shard_stats()
                .iter()
                .map(|st| st.payload_bytes)
                .sum();
            runs.push(ShardRun {
                key: format!("S={s}/seq"),
                shards: s,
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                total_s: tr.total_elapsed(),
                payload_bytes: payload / rounds,
                final_grad: tr.last_grad_norm(),
                per_shard: pool.shard_stats().to_vec(),
            });
        }
        {
            let mut pool = ShardedPool::new_threaded(make(), 2, 0);
            let tr = run_fednl_pool(
                &mut pool,
                &opts,
                vec![0.0; dd],
                "shard/S2thr",
            );
            let payload: u64 = pool
                .shard_stats()
                .iter()
                .map(|st| st.payload_bytes)
                .sum();
            runs.push(ShardRun {
                key: "S=2/threaded".into(),
                shards: 2,
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                total_s: tr.total_elapsed(),
                payload_bytes: payload / rounds,
                final_grad: tr.last_grad_norm(),
                per_shard: pool.shard_stats().to_vec(),
            });
        }
        {
            // Depth-3 tree: 2 top-level shards, each itself a
            // ShardedPool over 2 sub-shard aggregators — the
            // in-process analogue of a `relay --parent 2` tree. Exact
            // pre-reduction composes tier over tier, so this run joins
            // the bit-identity assertion below.
            let half = (n_clients / 2) as u32;
            let mk_inner = |part: Vec<ClientState>, lo: u32, hi: u32| {
                let mid = lo + (hi - lo) / 2;
                let mut a = part;
                let b = a.split_off((mid - lo) as usize);
                let subs: Vec<Box<dyn ClientPool>> =
                    vec![Box::new(SeqPool::new(a)), Box::new(SeqPool::new(b))];
                ShardedPool::from_shards(subs, vec![(lo, mid), (mid, hi)])
            };
            let mut lo_part = make();
            let hi_part = lo_part.split_off(half as usize);
            let top: Vec<Box<dyn ClientPool>> = vec![
                Box::new(mk_inner(lo_part, 0, half)),
                Box::new(mk_inner(hi_part, half, n_clients as u32)),
            ];
            let mut pool = ShardedPool::from_shards(
                top,
                vec![(0, half), (half, n_clients as u32)],
            );
            let tr = run_fednl_pool(
                &mut pool,
                &opts,
                vec![0.0; dd],
                "shard/deep",
            );
            let payload: u64 = pool
                .shard_stats()
                .iter()
                .map(|st| st.payload_bytes)
                .sum();
            runs.push(ShardRun {
                key: "deep/2x2/seq".into(),
                shards: 2,
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                total_s: tr.total_elapsed(),
                payload_bytes: payload / rounds,
                final_grad: tr.last_grad_norm(),
                per_shard: pool.shard_stats().to_vec(),
            });
        }
        let g0 = runs[0].final_grad;
        for r in &runs {
            assert_eq!(
                r.final_grad.to_bits(),
                g0.to_bits(),
                "{}: sharded trajectory diverged from flat",
                r.key
            );
        }
        // Payload independence of n (the pre-reduction claim): the
        // same topology at 2n clients — SHARD_SUM payload per round
        // stays O(S·d) while the flat atom payload doubles. Appended
        // after the bit-identity assertion (different problem, its
        // trajectory is not comparable to the n=12 runs).
        {
            let n2 = n_clients * 2;
            let mut pool = ShardedPool::new_seq(make_n(n2), 2);
            let tr = run_fednl_pool(
                &mut pool,
                &opts,
                vec![0.0; dd],
                "shard/S2n24",
            );
            let payload: u64 = pool
                .shard_stats()
                .iter()
                .map(|st| st.payload_bytes)
                .sum();
            runs.push(ShardRun {
                key: format!("S=2/seq/n{n2}"),
                shards: 2,
                wait_s: tr.wait_secs,
                aggregate_s: tr.aggregate_secs,
                total_s: tr.total_elapsed(),
                payload_bytes: payload / rounds,
                final_grad: tr.last_grad_norm(),
                per_shard: pool.shard_stats().to_vec(),
            });
        }
        for r in &runs {
            println!(
                "shard/{:<14} rounds={rounds}  wait {:>9.3}ms  aggregate {:>9.3}ms  total {:>9.3}ms  payload/round {} B",
                r.key,
                r.wait_s * 1e3,
                r.aggregate_s * 1e3,
                r.total_s * 1e3,
                r.payload_bytes
            );
            for st in &r.per_shard {
                println!(
                    "  shard {} ({} clients): wait {:>9.3}ms  aggregate {:>9.3}ms  msgs {}  payload {} B",
                    st.shard,
                    st.clients,
                    st.wait_s * 1e3,
                    st.aggregate_s * 1e3,
                    st.msgs,
                    st.payload_bytes
                );
            }
        }
        if json {
            let mut s = String::from("{\n");
            s.push_str(&format!(
                "  \"rounds\": {rounds}, \"n_clients\": {n_clients}, \"d\": {dd}, \"cores\": {},\n",
                fednl::utils::available_cores()
            ));
            s.push_str("  \"configs\": [\n");
            for (i, r) in runs.iter().enumerate() {
                s.push_str(&format!(
                    "    {{\"key\": \"{}\", \"shards\": {}, \"wait_s\": {:.6}, \"aggregate_s\": {:.6}, \"total_s\": {:.6}, \"payload_bytes\": {},\n",
                    r.key,
                    r.shards,
                    r.wait_s,
                    r.aggregate_s,
                    r.total_s,
                    r.payload_bytes
                ));
                s.push_str("     \"per_shard\": [");
                for (j, st) in r.per_shard.iter().enumerate() {
                    s.push_str(&format!(
                        "{}{{\"shard\": {}, \"clients\": {}, \"wait_s\": {:.6}, \"aggregate_s\": {:.6}, \"msgs\": {}, \"payload_bytes\": {}}}",
                        if j > 0 { ", " } else { "" },
                        st.shard,
                        st.clients,
                        st.wait_s,
                        st.aggregate_s,
                        st.msgs,
                        st.payload_bytes
                    ));
                }
                s.push_str("]}");
                s.push_str(if i + 1 < runs.len() { ",\n" } else { "\n" });
            }
            s.push_str("  ]\n}\n");
            match std::fs::write("BENCH_shard.json", s) {
                Ok(()) => {
                    println!("shard timings written to BENCH_shard.json")
                }
                Err(e) => {
                    eprintln!("failed to write BENCH_shard.json: {e}")
                }
            }
        }
    }

    if want("oracle") {
        let mut oracle = LogisticOracle::new(shard.clone(), 1e-3);
        let x = vec![0.05; d];
        let mut g = vec![0.0; d];
        let mut h = Mat::zeros(d, d);
        bench("oracle/fused loss+grad+hessian", 3, 20, || {
            let _ = oracle.loss_grad_hessian(&x, &mut g, &mut h);
        });
        bench("oracle/loss+grad only", 3, 50, || {
            let _ = oracle.loss_grad(&x, &mut g);
        });
        // §5.7 ablation-style: three separate evaluations recompute the
        // margins three times.
        bench("oracle/separate loss,grad,hess (3x margins)", 3, 20, || {
            let _ = oracle.loss(&x);
            oracle.grad(&x, &mut g);
            oracle.hessian(&x, &mut h);
        });
    }

    if want("solve") {
        let a = random_spd(d, 2);
        let mut rng = Pcg64::seed_from_u64(3);
        let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        bench("solve/cholesky (factor+subst)", 2, 20, || {
            let _ = cholesky::solve_spd(&a, 0.0, &b).unwrap();
        });
        bench("solve/gauss elimination", 2, 10, || {
            let _ = gauss::solve_gauss(&a, &b).unwrap();
        });
        bench("solve/conjugate gradient 1e-10", 2, 10, || {
            let _ = iterative::cg(&a, &b, 1e-10, 2000);
        });
    }

    if want("compress") {
        let pu = PackedUpper::new(d);
        let mut rng = Pcg64::seed_from_u64(4);
        let src: Vec<f64> =
            (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        for name in ALL_NAMES {
            let mut c = by_name(name, d, 8, 5).unwrap();
            let mut round = 0u64;
            bench(&format!("compress/{name} (packed n={})", pu.len()), 3, 30, || {
                let out = c.compress(&pu, &src, round);
                round += 1;
                std::hint::black_box(out);
            });
        }
    }

    if want("matmul") {
        let a = random_spd(128, 6);
        let b = random_spd(128, 7);
        bench("matmul/naive 128", 2, 10, || {
            std::hint::black_box(a.matmul_naive(&b));
        });
        for tile in [8, 32, 64] {
            bench(&format!("matmul/tiled{tile} 128"), 2, 10, || {
                std::hint::black_box(a.matmul_tiled(&b, tile));
            });
        }
    }

    if want("pjrt") {
        match fednl::runtime::PjrtRuntime::load("artifacts") {
            Ok(rt) => {
                let sh = random_shard(301, 350, 8);
                let mut native = LogisticOracle::new(sh.clone(), 1e-3);
                match rt.oracle_for_shard(&sh, 1e-3) {
                    Ok(mut pj) => {
                        let x = vec![0.05; 301];
                        let mut g = vec![0.0; 301];
                        let mut h = Mat::zeros(301, 301);
                        bench("pjrt/oracle fused (AOT JAX+Pallas)", 2, 10, || {
                            let _ = pj.loss_grad_hessian(&x, &mut g, &mut h);
                        });
                        bench("pjrt/native oracle (same shape)", 2, 10, || {
                            let _ = native.loss_grad_hessian(&x, &mut g, &mut h);
                        });
                    }
                    Err(e) => println!("pjrt oracle unavailable: {e}"),
                }
            }
            Err(_) => println!("(artifacts not built; skipping pjrt bench)"),
        }
    }
}

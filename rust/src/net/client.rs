//! Multi-node client: connects to the master, registers its shard id,
//! then serves FedNL / FedNL-LS / FedNL-PP commands until shutdown.
//!
//! Connection establishment is interleaved with dataset loading by the
//! caller (paper §7): the caller parses its shard while the TCP connect
//! happens, then hands both to [`run_client`].
//!
//! # Failover (`--fallback`)
//!
//! A client given fallback addresses ([`ClientOpts::fallback`])
//! registers with `REG_WANTS_ACK` and runs the commit-ack protocol:
//! each ROUND's Hᵢ shift is **staged** ([`ClientState::round_staged`])
//! and applied only on the master's `ROUND_ACK`. When its connection
//! dies mid-run — a severed relay kills its whole subtree — the client
//! rotates to the next address in `primary, fallback…` order,
//! re-REGISTERs warm, and resolves the staged shift against the
//! `RESYNC` commit watermark the adopter sends: applied iff the master
//! committed that round, discarded otherwise — exactly-once either
//! way, closing the "computed but reply lost" hole. An orderly end is
//! always an explicit SHUTDOWN frame, so EOF is never ambiguous.
//!
//! `--fresh` additionally announces `REG_FRESH` on the initial
//! registration: the process restarted with reset state, so the engine
//! re-pulls every client's packed Hᵢ (`PULL_H`) and rebuilds the exact
//! server-side average.

use std::net::TcpStream;

use anyhow::{Context, Result};

use super::framing::Channel;
use super::wire::{self, c2s, s2c};
use crate::algorithms::{ClientState, PPClientState};

/// Which algorithm family this client serves.
pub enum ClientMode {
    /// FedNL / FedNL-LS (Alg. 1/2 client loop).
    FedNL(ClientState),
    /// FedNL-PP (Alg. 3 client loop).
    PP(PPClientState),
}

/// Optional client-side behaviors (fault drills and tests).
#[derive(Debug, Clone, Default)]
pub struct ClientOpts {
    /// After answering this many ROUND commands, announce a graceful
    /// leave (`DEREGISTER`) and exit — simulating a departing client.
    /// The master retires the connection and, under a quorum round
    /// policy, keeps training on the survivors; this id may later
    /// rejoin by running a fresh `run_client`.
    pub leave_after_rounds: Option<u64>,
    /// Addresses to fail over to (in order, after the primary) when
    /// the current connection dies mid-run. Non-empty enables the
    /// commit-ack protocol (`REG_WANTS_ACK`); FedNL-family only.
    pub fallback: Vec<String>,
    /// Announce `REG_FRESH` on the initial registration: this process
    /// restarted with reset state and needs the exact Hᵢ resync.
    pub fresh: bool,
}

/// How one serve session over a single channel ended.
enum Served {
    /// Orderly end: SHUTDOWN, or the scripted graceful leave.
    Done,
    /// The connection died mid-run — rotate to the next address.
    Lost,
}

/// Connect to `addr`, register as `client_id`, serve until SHUTDOWN.
/// Returns (bytes_sent, bytes_received).
pub fn run_client(
    addr: &str,
    client_id: usize,
    mode: ClientMode,
) -> Result<(u64, u64)> {
    run_client_with(addr, client_id, mode, ClientOpts::default())
}

/// As [`run_client`], with explicit [`ClientOpts`].
pub fn run_client_with(
    addr: &str,
    client_id: usize,
    mut mode: ClientMode,
    opts: ClientOpts,
) -> Result<(u64, u64)> {
    let (d, family) = match &mode {
        ClientMode::FedNL(c) => (c.dim(), wire::FAMILY_FEDNL),
        ClientMode::PP(c) => (c.dim(), wire::FAMILY_PP),
    };
    let wants_ack = !opts.fallback.is_empty();
    anyhow::ensure!(
        !wants_ack || matches!(mode, ClientMode::FedNL(_)),
        "--fallback failover runs the commit-ack protocol, which \
         stages the FedNL Hᵢ shift; PP clients have no staged state"
    );
    anyhow::ensure!(
        !opts.fresh || matches!(mode, ClientMode::FedNL(_)),
        "--fresh is a FedNL Hᵢ resync; PP clients carry no Hᵢ"
    );
    let addrs: Vec<&str> = std::iter::once(addr)
        .chain(opts.fallback.iter().map(|s| s.as_str()))
        .collect();
    // Cleared once a registration demonstrably landed (first inbound
    // frame): a REGISTER lost with its connection must be re-announced
    // fresh, or the engine would skip the exact resync.
    let mut fresh_pending = opts.fresh;
    let mut next_addr = 0usize;
    let mut rounds_served = 0u64;
    let mut total = (0u64, 0u64);
    loop {
        let target = addrs[next_addr % addrs.len()];
        let stream = connect_with_retry(target, 50)?;
        let mut ch = Channel::new(stream)?;
        let mut flags = 0u8;
        if wants_ack {
            flags |= wire::REG_WANTS_ACK;
        }
        if fresh_pending {
            flags |= wire::REG_FRESH;
        }
        let registered = ch.send(
            c2s::REGISTER,
            &wire::encode_register(
                client_id as u32,
                d as u32,
                family,
                flags,
            ),
        );
        let served = match registered {
            Ok(()) => serve(
                &mut ch,
                &mut mode,
                &opts,
                wants_ack,
                &mut rounds_served,
                &mut fresh_pending,
            ),
            // A failover client that cannot even register rotates on;
            // anyone else reports the broken connection.
            Err(e) if !wants_ack => Err(e),
            Err(_) => Ok(Served::Lost),
        };
        total.0 += ch.bytes_sent;
        total.1 += ch.bytes_received;
        match served? {
            Served::Done => return Ok(total),
            Served::Lost => next_addr += 1,
        }
    }
}

/// Send that maps a failover client's dead connection to a pending
/// rotation instead of an error: `Ok(true)` = sent, `Ok(false)` =
/// lost (only when failover is allowed).
fn fsend(
    ch: &mut Channel,
    wants_ack: bool,
    tag: u8,
    payload: &[u8],
) -> Result<bool> {
    match ch.send(tag, payload) {
        Ok(()) => Ok(true),
        Err(_) if wants_ack => Ok(false),
        Err(e) => Err(e),
    }
}

/// Serve one registered channel until it ends. Decode failures and
/// protocol violations stay hard errors; only *connection* loss turns
/// into [`Served::Lost`] (and only for failover clients).
fn serve(
    ch: &mut Channel,
    mode: &mut ClientMode,
    opts: &ClientOpts,
    wants_ack: bool,
    rounds_served: &mut u64,
    fresh_pending: &mut bool,
) -> Result<Served> {
    loop {
        let (tag, payload) = match ch.recv() {
            Ok(f) => f,
            Err(_) if wants_ack => return Ok(Served::Lost),
            Err(e) => return Err(e),
        };
        // Any inbound frame proves the registration was admitted.
        *fresh_pending = false;
        match tag {
            s2c::ROUND => {
                // Unified round command: a FedNL client answers with
                // its Alg. 1 message, a PP client with its Alg. 3
                // participation deltas — same MSG codec either way.
                let (x, round, need_loss) = wire::decode_round(&payload)?;
                let msg = match mode {
                    // Failover clients stage the shift; it lands on
                    // ROUND_ACK (or a favorable rejoin RESYNC).
                    ClientMode::FedNL(c) if wants_ack => {
                        c.round_staged(&x, round, need_loss)
                    }
                    ClientMode::FedNL(c) => c.round(&x, round, need_loss),
                    ClientMode::PP(c) => {
                        c.participate(&x, round, need_loss)
                    }
                };
                if !fsend(
                    ch,
                    wants_ack,
                    c2s::MSG,
                    &wire::encode_client_msg(&msg),
                )? {
                    return Ok(Served::Lost);
                }
                *rounds_served += 1;
                if let Some(k) = opts.leave_after_rounds {
                    if *rounds_served >= k {
                        let _ = ch.send(c2s::DEREGISTER, &[]);
                        return Ok(Served::Done);
                    }
                }
            }
            s2c::ROUND_ACK => {
                let c = match mode {
                    ClientMode::FedNL(c) => c,
                    _ => anyhow::bail!("ROUND_ACK sent to a PP client"),
                };
                c.commit_staged(wire::decode_round_ack(&payload)?);
            }
            s2c::RESYNC => {
                let c = match mode {
                    ClientMode::FedNL(c) => c,
                    _ => anyhow::bail!("RESYNC sent to a PP client"),
                };
                c.resolve_staged(wire::decode_resync(&payload)?);
            }
            s2c::PULL_H => {
                // Sent to *every* client when some fresh rejoiner
                // needs the exact server-side H rebuilt — not gated on
                // this client's own flags.
                let c = match mode {
                    ClientMode::FedNL(c) => c,
                    _ => anyhow::bail!("PULL_H sent to a PP client"),
                };
                let packed = c.packed_h();
                if !fsend(
                    ch,
                    wants_ack,
                    c2s::WARM,
                    &wire::encode_vec(&packed),
                )? {
                    return Ok(Served::Lost);
                }
            }
            s2c::EVAL_LOSS => {
                let x = wire::decode_vec(&payload)?;
                let l = match mode {
                    ClientMode::FedNL(c) => c.eval_loss(&x),
                    ClientMode::PP(c) => c.oracle.loss(&x),
                };
                if !fsend(ch, wants_ack, c2s::LOSS, &wire::encode_scalar(l))?
                {
                    return Ok(Served::Lost);
                }
            }
            s2c::WARM_START => {
                let x = wire::decode_vec(&payload)?;
                let packed = match mode {
                    ClientMode::FedNL(c) => c.warm_start(&x),
                    _ => anyhow::bail!("WARM_START sent to a PP client"),
                };
                if !fsend(
                    ch,
                    wants_ack,
                    c2s::WARM,
                    &wire::encode_vec(&packed),
                )? {
                    return Ok(Served::Lost);
                }
            }
            s2c::LOSS_GRAD => {
                let x = wire::decode_vec(&payload)?;
                let (l, g) = match mode {
                    ClientMode::FedNL(c) => c.eval_loss_grad(&x),
                    ClientMode::PP(c) => {
                        let mut g = vec![0.0; x.len()];
                        let l = c.oracle.loss_grad(&x, &mut g);
                        (l, g)
                    }
                };
                if !fsend(
                    ch,
                    wants_ack,
                    c2s::GRAD,
                    &wire::encode_loss_grad(l, &g),
                )? {
                    return Ok(Served::Lost);
                }
            }
            s2c::STATE => {
                let c = match mode {
                    ClientMode::PP(c) => c,
                    _ => anyhow::bail!("STATE sent to a FedNL client"),
                };
                if !fsend(
                    ch,
                    wants_ack,
                    c2s::STATE,
                    &wire::encode_loss_grad(c.l_i, &c.g_i),
                )? {
                    return Ok(Served::Lost);
                }
            }
            s2c::SET_ALPHA => {
                let a = wire::decode_scalar(&payload)?;
                let effective = match mode {
                    ClientMode::FedNL(c) => {
                        if a.is_finite() && a > 0.0 {
                            c.alpha = a;
                        }
                        c.alpha
                    }
                    ClientMode::PP(c) => {
                        if a.is_finite() && a > 0.0 {
                            c.alpha = a;
                        }
                        c.alpha
                    }
                };
                if !fsend(
                    ch,
                    wants_ack,
                    c2s::ACK,
                    &wire::encode_scalar(effective),
                )? {
                    return Ok(Served::Lost);
                }
            }
            s2c::SHUTDOWN => return Ok(Served::Done),
            other => anyhow::bail!("unknown command tag {other}"),
        }
    }
}

/// The master may come up after the clients (Slurm-style co-scheduling;
/// same for relays connecting upward): retry the connect with backoff.
pub(crate) fn connect_with_retry(
    addr: &str,
    attempts: u32,
) -> Result<TcpStream> {
    let mut delay = std::time::Duration::from_millis(20);
    for i in 0..attempts {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if i + 1 < attempts => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(std::time::Duration::from_secs(1));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connect {addr}"))
            }
        }
    }
    unreachable!()
}

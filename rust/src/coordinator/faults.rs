//! Deterministic fault injection for any [`ClientPool`] transport.
//!
//! [`FaultPool`] wraps an inner pool and imposes a [`FaultPlan`] — a
//! reproducible schedule of kills, dropped rounds and reply delays —
//! entirely on the master side. Because every injected outcome is a
//! pure function of (plan, round) and never of wall-clock races, the
//! same plan produces **bit-identical trajectories** on `SeqPool`,
//! `ThreadedPool` and `RemotePool` (asserted by the fault-injection
//! integration tests): the lossy-round extension of the coordinator's
//! buffer-and-commit determinism rule.
//!
//! # Injection semantics
//!
//! * `kill@R:C[-R2]` — client C is frozen from round R (inclusive)
//!   until round R2 (exclusive; absent = forever): it is not scheduled,
//!   is reported through [`ClientPool::dead_clients`], and on thawing
//!   is reported through [`ClientPool::take_rejoined`] so the driver
//!   can resync it (FedNL-PP pulls its STATE; a frozen client's state
//!   never moved, so the resync is exact on every transport).
//! * `drop@R:C` — client C does not participate in round R only.
//! * `delay@R:C:MS` — client C's round-R reply is withheld for MS
//!   milliseconds (a straggler). If MS exceeds the reply deadline of
//!   the active [`RoundPolicy`], the delay deterministically becomes a
//!   drop — the schedule decides, not the clock. The *certificate*,
//!   however, only lands once the deadline has elapsed from submit:
//!   a real transport cannot know a straggler is lost until its reply
//!   deadline expires, so the wrapper reproduces that detection
//!   latency instead of certifying clairvoyantly. The missing set is
//!   still schedule-decided; only the instant within the round at
//!   which it is reported is wall-clock.
//! * `killrelay@R:S` — shard S's **aggregator** dies at round R: its
//!   whole partition misses round R and rejoins at R+1 (the adoption
//!   heal). On transports with a shard layout the event is desugared
//!   into per-client kill spans (deterministic bookkeeping); on the
//!   relay tier the shard's channel is additionally severed for real
//!   ([`ClientPool::kill_shard`]), so clients fail over to the master
//!   and the partition-adoption path runs end-to-end — with a
//!   trajectory bit-identical to the desugared flat reference.
//! * `corrupt@R:C:MODE` — client C turns **Byzantine** for round R:
//!   its reply is mutated in the wrapper before commit. MODE is one of
//!   `scale:K` (gradient and Hessian update scaled by K), `signflip`
//!   (both negated), `garbage` (both replaced by a seeded random
//!   payload — the PRG seed is a pure function of (round, client), so
//!   the garbage is the same bytes on every transport) or `zero`
//!   (both zeroed; the message still arrives, distinguishing a silent
//!   attacker from a crash). `l_i` and the optional loss stay honest:
//!   the schema corrupts exactly the aggregated model quantities, so
//!   defenses are evaluated against the update channel they guard.
//!   A corruption round latches the wrapper's per-message atom
//!   fallback (like injected delays): shard tiers forward per-client
//!   atoms that round and the mutation lands master-side before the
//!   fold — `drain_sums` callers stay bit-identical by the exactness
//!   of the reproducible summation layer, and no new wire tags exist.
//! * `delaydist@R1-R2:lognormal:MU:SIGMA` — every client's reply in
//!   rounds [R1, R2) is withheld for a **drawn** number of
//!   milliseconds, ⌊exp(MU + SIGMA·z)⌉ with z a standard Gaussian
//!   from a Pcg64 seeded by a pure function of (round, client) — the
//!   same seeding discipline as `garbage`, so the same plan draws the
//!   same stragglers on every transport. Draws beyond the reply
//!   deadline become drops exactly like scripted `delay@` events; a
//!   scripted `delay@R:C:MS` naming the same (round, client) takes
//!   precedence over the distribution.
//! * `killmaster@R` — the **coordinator** dies entering round R. The
//!   engine reacts by dropping its aggregate state and rebuilding it
//!   from the latest durable checkpoint ([`super::checkpoint`]); the
//!   `crashsmoke` harness SIGKILLs and relaunches the real master
//!   process at the same schedule point. Requires checkpointing
//!   (`--checkpoint-dir`); the restored trajectory is bit-identical
//!   to the uninterrupted run.
//!
//! Faults suppress the ROUND *delivery*: a faulted client never
//! computes the round, so its local Hessian shift never advances and
//! client/master bookkeeping stays consistent on every transport. The
//! realistic "client computed but the reply was lost" failure is
//! closed by the commit-ack protocol (`net::wire`): failover clients
//! stage each round's shift until the master's `ROUND_ACK`, so a
//! computed-but-uncommitted round leaves the client bitwise identical
//! to the frozen semantics injected here. Logical byte accounting
//! in the drivers still charges the suppressed command frames: the
//! drop is modeled at the transport boundary.
//!
//! [`RoundPolicy`]: crate::algorithms::RoundPolicy

use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::{ClientFamily, ClientPool, RoundMode};
use crate::algorithms::{ClientMsg, RoundSum};
use crate::linalg::reduce::{RepAcc, RepVec};
use crate::rng::{Pcg64, Rng};

/// One frozen interval of a client: [`from`, `until`) in rounds.
///
/// [`from`]: KillSpan::from
/// [`until`]: KillSpan::until
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillSpan {
    pub client: u32,
    pub from: u64,
    /// First round the client is alive again; `None` = never rejoins.
    pub until: Option<u64>,
}

/// How a Byzantine client mutates its round reply (`corrupt@R:C:MODE`
/// in the schema; see the module docs for the exact semantics).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CorruptMode {
    /// Gradient and Hessian update scaled by K (`scale:K`).
    Scale(f64),
    /// Gradient and Hessian update negated (`signflip`).
    SignFlip,
    /// Gradient and Hessian-update values replaced by a seeded random
    /// payload (`garbage`). The PRG seed is a pure function of
    /// (round, client): identical bytes on every transport.
    Garbage,
    /// Gradient and Hessian update zeroed (`zero`) — the reply still
    /// arrives, so the attack is invisible to liveness accounting.
    Zero,
}

impl CorruptMode {
    fn parse(s: &str, ev: &str) -> Result<Self> {
        match s {
            "signflip" => Ok(Self::SignFlip),
            "garbage" => Ok(Self::Garbage),
            "zero" => Ok(Self::Zero),
            _ => {
                if let Some(k) = s.strip_prefix("scale:") {
                    let k: f64 = num(k, ev)?;
                    if !k.is_finite() {
                        bail!(
                            "fault event '{ev}': corrupt scale must \
                             be finite, got '{k}'"
                        );
                    }
                    Ok(Self::Scale(k))
                } else {
                    bail!(
                        "fault event '{ev}': unknown corrupt mode \
                         '{s}' (expected scale:K | signflip | \
                         garbage | zero)"
                    )
                }
            }
        }
    }

    fn to_spec(self) -> String {
        match self {
            // `{}` prints the shortest round-trippable f64, so
            // parse(to_spec()) restores the exact bits.
            Self::Scale(k) => format!("scale:{k}"),
            Self::SignFlip => "signflip".to_string(),
            Self::Garbage => "garbage".to_string(),
            Self::Zero => "zero".to_string(),
        }
    }
}

/// A reproducible fault schedule (see the module docs for the textual
/// schema parsed by [`FaultPlan::parse`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub kills: Vec<KillSpan>,
    /// (round, client) participations to drop.
    pub drops: Vec<(u64, u32)>,
    /// (round, client, milliseconds) reply delays.
    pub delays: Vec<(u64, u32, u64)>,
    /// (round, shard) relay kills: shard S's aggregator dies at round
    /// R — its whole partition misses round R and is adopted/rejoined
    /// at R+1. Desugared into per-client [`KillSpan`]s once the shard
    /// layout is known ([`FaultPlan::desugar_relay_kills`]); on the
    /// relay tier the kill additionally severs the real channel
    /// ([`super::ClientPool::kill_shard`]) so partition adoption runs
    /// end-to-end.
    pub relay_kills: Vec<(u64, u32)>,
    /// (round, client, mode) Byzantine reply corruptions. Multiple
    /// entries for the same (round, client) compose in plan order.
    pub corruptions: Vec<(u64, u32, CorruptMode)>,
    /// (from, until, mu, sigma) lognormal straggler-delay windows:
    /// every reply in rounds [from, until) is held for a seeded
    /// per-(round, client) draw of ⌊exp(mu + sigma·z)⌉ ms.
    pub delay_dists: Vec<(u64, u64, f64, f64)>,
    /// Rounds at which the coordinator itself dies (`killmaster@R`):
    /// the engine rebuilds from the latest durable checkpoint.
    pub master_kills: Vec<u64>,
}

fn num<T: std::str::FromStr>(s: &str, ev: &str) -> Result<T> {
    s.parse().map_err(|_| anyhow!("fault event '{ev}': bad number '{s}'"))
}

impl FaultPlan {
    /// The empty plan (injects nothing).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.drops.is_empty()
            && self.delays.is_empty()
            && self.relay_kills.is_empty()
            && self.corruptions.is_empty()
            && self.delay_dists.is_empty()
            && self.master_kills.is_empty()
    }

    /// Parse the CLI schema: comma-separated events, each
    /// `kill@R:C[-R2]` | `drop@R:C` | `delay@R:C:MS` |
    /// `killrelay@R:S` | `corrupt@R:C:MODE` (MODE one of
    /// `scale:K` | `signflip` | `garbage` | `zero`) |
    /// `delaydist@R1-R2:lognormal:MU:SIGMA` | `killmaster@R`.
    ///
    /// ```text
    /// kill@6:1-18,delay@3:2:25,drop@12:0,corrupt@4:1:scale:100
    /// ```
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        for ev in spec.split(',') {
            let ev = ev.trim();
            if ev.is_empty() {
                continue;
            }
            let Some((kind, rest)) = ev.split_once('@') else {
                bail!("fault event '{ev}': expected kind@round:client");
            };
            // Events whose round field is not a plain integer before
            // the first ':' are handled before the generic split:
            // killmaster@R carries no argument at all, delaydist's
            // round field is a span.
            if kind == "killmaster" {
                plan.master_kills.push(num(rest, ev)?);
                continue;
            }
            if kind == "delaydist" {
                let Some((span, dist)) = rest.split_once(':') else {
                    bail!(
                        "fault event '{ev}': expected \
                         delaydist@R1-R2:lognormal:MU:SIGMA"
                    );
                };
                let Some((from, until)) = span.split_once('-') else {
                    bail!(
                        "fault event '{ev}': expected round span R1-R2"
                    );
                };
                let from: u64 = num(from, ev)?;
                let until: u64 = num(until, ev)?;
                if until <= from {
                    bail!(
                        "fault event '{ev}': span end {until} <= \
                         start {from}"
                    );
                }
                let Some(params) = dist.strip_prefix("lognormal:")
                else {
                    bail!(
                        "fault event '{ev}': unknown delay \
                         distribution (expected lognormal:MU:SIGMA)"
                    );
                };
                let Some((mu, sigma)) = params.split_once(':') else {
                    bail!(
                        "fault event '{ev}': expected lognormal:MU:SIGMA"
                    );
                };
                let mu: f64 = num(mu, ev)?;
                let sigma: f64 = num(sigma, ev)?;
                if !mu.is_finite() || !sigma.is_finite() || sigma < 0.0 {
                    bail!(
                        "fault event '{ev}': mu must be finite and \
                         sigma finite and >= 0"
                    );
                }
                plan.delay_dists.push((from, until, mu, sigma));
                continue;
            }
            let Some((round, args)) = rest.split_once(':') else {
                bail!("fault event '{ev}': expected kind@round:client");
            };
            let round: u64 = num(round, ev)?;
            match kind {
                "kill" => {
                    let (client, until) = match args.split_once('-') {
                        Some((c, r2)) => (c, Some(num(r2, ev)?)),
                        None => (args, None),
                    };
                    let client = num(client, ev)?;
                    if let Some(u) = until {
                        if u <= round {
                            bail!("fault event '{ev}': rejoin {u} <= kill {round}");
                        }
                    }
                    plan.kills.push(KillSpan {
                        client,
                        from: round,
                        until,
                    });
                }
                "drop" => {
                    plan.drops.push((round, num(args, ev)?));
                }
                "killrelay" => {
                    plan.relay_kills.push((round, num(args, ev)?));
                }
                "delay" => {
                    let Some((client, ms)) = args.split_once(':') else {
                        bail!("fault event '{ev}': expected delay@round:client:ms");
                    };
                    plan.delays.push((round, num(client, ev)?, num(ms, ev)?));
                }
                "corrupt" => {
                    // MODE may itself carry a ':' (scale:K), so split
                    // the client off first and hand the rest to the
                    // mode parser.
                    let Some((client, mode)) = args.split_once(':')
                    else {
                        bail!(
                            "fault event '{ev}': expected \
                             corrupt@round:client:mode"
                        );
                    };
                    plan.corruptions.push((
                        round,
                        num(client, ev)?,
                        CorruptMode::parse(mode, ev)?,
                    ));
                }
                other => bail!("unknown fault kind '{other}' in '{ev}'"),
            }
        }
        Ok(plan)
    }

    /// Serialize back to the CLI schema parsed by [`FaultPlan::parse`]
    /// (`parse(p.to_spec()) == p` for every plan; round-trip tested).
    pub fn to_spec(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for k in &self.kills {
            match k.until {
                Some(u) => {
                    parts.push(format!("kill@{}:{}-{u}", k.from, k.client))
                }
                None => parts.push(format!("kill@{}:{}", k.from, k.client)),
            }
        }
        for &(r, c) in &self.drops {
            parts.push(format!("drop@{r}:{c}"));
        }
        for &(r, c, ms) in &self.delays {
            parts.push(format!("delay@{r}:{c}:{ms}"));
        }
        for &(r, s) in &self.relay_kills {
            parts.push(format!("killrelay@{r}:{s}"));
        }
        for &(r, c, m) in &self.corruptions {
            parts.push(format!("corrupt@{r}:{c}:{}", m.to_spec()));
        }
        for &(from, until, mu, sigma) in &self.delay_dists {
            // `{}` prints the shortest round-trippable f64, so
            // parse(to_spec()) restores the exact bits.
            parts.push(format!(
                "delaydist@{from}-{until}:lognormal:{mu}:{sigma}"
            ));
        }
        for &r in &self.master_kills {
            parts.push(format!("killmaster@{r}"));
        }
        parts.join(",")
    }

    /// Builder: freeze `client` from `from` until `until` (exclusive).
    pub fn with_kill(mut self, client: u32, from: u64, until: Option<u64>) -> Self {
        self.kills.push(KillSpan {
            client,
            from,
            until,
        });
        self
    }

    /// Builder: drop `client`'s participation in `round`.
    pub fn with_drop(mut self, round: u64, client: u32) -> Self {
        self.drops.push((round, client));
        self
    }

    /// Builder: delay `client`'s round-`round` reply by `ms`.
    pub fn with_delay(mut self, round: u64, client: u32, ms: u64) -> Self {
        self.delays.push((round, client, ms));
        self
    }

    /// Builder: kill shard `shard`'s relay at round `round` (partition
    /// misses `round`, adopted/rejoined at `round + 1`).
    pub fn with_relay_kill(mut self, round: u64, shard: u32) -> Self {
        self.relay_kills.push((round, shard));
        self
    }

    /// Builder: make `client` Byzantine for `round` with `mode`.
    pub fn with_corrupt(
        mut self,
        round: u64,
        client: u32,
        mode: CorruptMode,
    ) -> Self {
        self.corruptions.push((round, client, mode));
        self
    }

    /// Builder: lognormal straggler delays over rounds [from, until).
    pub fn with_delay_dist(
        mut self,
        from: u64,
        until: u64,
        mu: f64,
        sigma: f64,
    ) -> Self {
        self.delay_dists.push((from, until, mu, sigma));
        self
    }

    /// Builder: kill the coordinator entering `round` (rebuilt from
    /// the latest durable checkpoint).
    pub fn with_master_kill(mut self, round: u64) -> Self {
        self.master_kills.push(round);
        self
    }

    /// Lower every relay kill onto per-client [`KillSpan`]s against
    /// the given contiguous shard partition (`ranges[s] = (lo, hi)`):
    /// `killrelay@R:S` ≡ `kill@R:c-(R+1)` for every c in S's range —
    /// the partition misses exactly round R and rejoins at R+1, which
    /// is precisely what the relay tier's adoption path observably
    /// does. The `relay_kills` themselves are kept (the relay tier
    /// still severs the real channel); callers relying on this plan's
    /// bookkeeping alone get the bit-identical flat equivalent.
    pub fn desugar_relay_kills(&mut self, ranges: &[(u32, u32)]) {
        for &(round, shard) in &self.relay_kills {
            let (lo, hi) = *ranges
                .get(shard as usize)
                .unwrap_or_else(|| {
                    panic!(
                        "killrelay names shard {shard} but the layout \
                         has {} shards",
                        ranges.len()
                    )
                });
            for client in lo..hi {
                self.kills.push(KillSpan {
                    client,
                    from: round,
                    until: Some(round + 1),
                });
            }
        }
    }

    /// Is `client` frozen at `round`?
    pub fn dead_at(&self, client: u32, round: u64) -> bool {
        self.kills.iter().any(|k| {
            let open = match k.until {
                Some(u) => round < u,
                None => true,
            };
            k.client == client && round >= k.from && open
        })
    }

    fn dropped_at(&self, client: u32, round: u64) -> bool {
        self.drops.iter().any(|&(r, c)| r == round && c == client)
    }

    fn delay_at(&self, client: u32, round: u64) -> Option<u64> {
        self.delays
            .iter()
            .find(|&&(r, c, _)| r == round && c == client)
            .map(|&(_, _, ms)| ms)
    }

    /// The distributional delay for (client, round), if a
    /// `delaydist` window covers the round: ⌊exp(mu + sigma·z)⌉ ms
    /// with z drawn from a Pcg64 seeded purely by (round, client) —
    /// the same draw on every transport. The first matching window
    /// wins (windows compose by order, like scripted events).
    fn dist_delay_at(&self, client: u32, round: u64) -> Option<u64> {
        self.delay_dists
            .iter()
            .find(|&&(from, until, _, _)| round >= from && round < until)
            .map(|&(_, _, mu, sigma)| {
                dist_delay_ms(mu, sigma, round, client)
            })
    }

    /// Scripted delays take precedence over distributional draws.
    fn effective_delay_at(&self, client: u32, round: u64) -> Option<u64> {
        self.delay_at(client, round)
            .or_else(|| self.dist_delay_at(client, round))
    }

    fn max_client(&self) -> Option<u32> {
        let kills = self.kills.iter().map(|k| k.client);
        let drops = self.drops.iter().map(|&(_, c)| c);
        let delays = self.delays.iter().map(|&(_, c, _)| c);
        let corrupts = self.corruptions.iter().map(|&(_, c, _)| c);
        kills.chain(drops).chain(delays).chain(corrupts).max()
    }
}

/// The `garbage` payload PRG seed: a pure function of (round, client)
/// so the same plan yields the same bytes on every transport.
fn garbage_seed(round: u64, client: u32) -> u64 {
    round
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((client as u64) << 17)
        ^ 0xBAD5_EED0_C0FF_EE00
}

/// The `delaydist` draw PRG seed — same discipline as
/// [`garbage_seed`], different tweak constant so a plan combining
/// both never correlates its garbage bytes with its straggler draws.
fn dist_seed(round: u64, client: u32) -> u64 {
    round
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((client as u64) << 17)
        ^ 0xD15C_0DE1_5EED_F00D
}

/// One lognormal delay draw in milliseconds: ⌊exp(mu + sigma·z)⌉ with
/// z standard Gaussian. Pure in (mu, sigma, round, client); the f64 →
/// u64 cast saturates, so extreme draws become effectively-infinite
/// delays (a drop under any reply deadline) rather than wrapping.
fn dist_delay_ms(mu: f64, sigma: f64, round: u64, client: u32) -> u64 {
    let mut rng = Pcg64::seed_from_u64(dist_seed(round, client));
    let z = rng.next_gaussian();
    (mu + sigma * z).exp().round() as u64
}

/// Mutate one committed reply according to `mode` (module docs list
/// the exact semantics per mode). Structure-preserving except `zero`'s
/// neutralized scale: index payloads, value-vector lengths and the
/// encoding stay as sent, so logical byte accounting stays identical
/// across transports.
fn corrupt_msg(
    m: &mut ClientMsg,
    mode: CorruptMode,
    round: u64,
    client: u32,
) {
    match mode {
        CorruptMode::Scale(k) => {
            for g in &mut m.grad {
                *g *= k;
            }
            m.update.scale *= k;
        }
        CorruptMode::SignFlip => {
            for g in &mut m.grad {
                *g = -*g;
            }
            m.update.scale = -m.update.scale;
        }
        CorruptMode::Zero => {
            for g in &mut m.grad {
                *g = 0.0;
            }
            // scale = 0 zeroes every update entry while keeping the
            // payload shape (and wire size) exactly as sent; the
            // superaccumulator absorbs signed zeros as no-ops.
            m.update.scale = 0.0;
        }
        CorruptMode::Garbage => {
            let mut rng =
                Pcg64::seed_from_u64(garbage_seed(round, client));
            for g in &mut m.grad {
                *g = rng.next_gaussian();
            }
            for v in &mut m.update.values {
                *v = rng.next_gaussian();
            }
            m.update.scale = 1.0;
        }
    }
}

/// Imposes a [`FaultPlan`] on any inner [`ClientPool`] (see the module
/// docs). Faults injected here combine with real transport failures
/// the inner pool reports (`RemotePool` deadline/EOF deregistrations
/// pass through untouched).
pub struct FaultPool<P: ClientPool> {
    inner: P,
    plan: FaultPlan,
    deadline: Option<Duration>,
    /// Frozen flags as of the last prepared round (rejoin detection).
    dead: Vec<bool>,
    missing: Vec<u32>,
    rejoined: Vec<u32>,
    /// (client, release instant) reply holds for the round in flight.
    holds: Vec<(u32, Instant)>,
    /// Over-deadline stragglers of the round in flight: lost by the
    /// schedule, but certified missing only once the reply deadline
    /// expires (client, deadline instant) — see the module docs.
    late_certs: Vec<(u32, Instant)>,
    /// The engine's requested reply-aggregation mode.
    mode: RoundMode,
    /// Latched per round at submit: injected delays and corruptions
    /// need per-message atom visibility, so such a round drops to the
    /// atom path (exactness keeps the trajectory bit-identical either
    /// way).
    round_atoms: bool,
    /// Corruptions scheduled for the round in flight (client, mode),
    /// resolved against the live set at submit; applied to matching
    /// replies as they pass through [`Self::drain`].
    corrupt_now: Vec<(u32, CorruptMode)>,
    /// The round in flight (seeds the `garbage` payload PRG).
    corrupt_round: u64,
    /// Relay kills to apply natively — (round, shard, applied). Only
    /// populated when the inner pool supports a real shard kill; the
    /// plan's desugared per-client spans carry the deterministic
    /// bookkeeping either way, the native kill additionally severs the
    /// channel so partition adoption runs for real.
    native_kills: Vec<(u64, u32, bool)>,
}

impl<P: ClientPool> FaultPool<P> {
    pub fn new(inner: P, plan: FaultPlan) -> Self {
        let ranges = inner.shard_ranges();
        Self::build(inner, plan, ranges)
    }

    /// [`FaultPool::new`] with an explicit shard layout: lets
    /// `killrelay@R:S` events run on **flat** transports (SeqPool,
    /// ThreadedPool, RemotePool, EventPool) by desugaring them against
    /// the same contiguous partition [`super::shard::partition`] would
    /// produce — the flat reference trajectory a relay-tree failover
    /// run must match bitwise.
    pub fn with_shard_layout(
        inner: P,
        plan: FaultPlan,
        n_shards: usize,
    ) -> Self {
        let ranges = super::shard::partition(inner.n_clients(), n_shards);
        Self::build(inner, plan, Some(ranges))
    }

    fn build(
        inner: P,
        mut plan: FaultPlan,
        ranges: Option<Vec<(u32, u32)>>,
    ) -> Self {
        let n = inner.n_clients();
        let mut native_kills = Vec::new();
        if !plan.relay_kills.is_empty() {
            let ranges = ranges.unwrap_or_else(|| {
                panic!(
                    "killrelay@R:S needs a shard layout: wrap a sharded \
                     transport or use FaultPool::with_shard_layout"
                )
            });
            plan.desugar_relay_kills(&ranges);
            if inner.supports_shard_kill() {
                native_kills = plan
                    .relay_kills
                    .iter()
                    .map(|&(r, s)| (r, s, false))
                    .collect();
            }
        }
        if let Some(c) = plan.max_client() {
            assert!(
                (c as usize) < n,
                "fault plan names client {c} but the pool has {n} clients"
            );
        }
        Self {
            inner,
            plan,
            deadline: None,
            dead: vec![false; n],
            missing: Vec::new(),
            rejoined: Vec::new(),
            holds: Vec::new(),
            late_certs: Vec::new(),
            mode: RoundMode::Atoms,
            round_atoms: true,
            corrupt_now: Vec::new(),
            corrupt_round: 0,
            native_kills,
        }
    }

    pub fn into_inner(self) -> P {
        self.inner
    }

    pub fn inner_mut(&mut self) -> &mut P {
        &mut self.inner
    }

    /// Re-prime the rejoin-detection flags for a restored master
    /// resuming at `round`: a freshly constructed wrapper starts with
    /// every client live, so a client whose kill span ends exactly at
    /// the resume round would otherwise never surface through
    /// [`ClientPool::take_rejoined`] (and would miss its resync).
    /// Also marks native relay kills scheduled before `round` as
    /// already applied. Call once, before the resumed run's first
    /// `prepare_round`; a fault wrapper that survived in-process
    /// (`killmaster@R` on Seq/Threaded pools) keeps its live flags
    /// and must *not* be re-primed.
    pub fn prime_liveness(&mut self, round: u64) {
        if round == 0 {
            return;
        }
        for (c, dead) in self.dead.iter_mut().enumerate() {
            *dead = self.plan.dead_at(c as u32, round - 1);
        }
        for nk in &mut self.native_kills {
            if nk.0 < round {
                nk.2 = true;
            }
        }
    }

    /// An injected delay longer than the reply deadline is a drop —
    /// decided by the schedule, never by the clock. The certificate
    /// lands at deadline expiry (see [`Self::flush_late_certs`]).
    fn delay_becomes_drop(&self, ms: u64) -> bool {
        self.deadline.is_some_and(|dl| Duration::from_millis(ms) > dl)
    }

    /// Block until every pending over-deadline straggler's reply
    /// deadline has expired, then certify them missing. Called once
    /// the inner pool has no further replies this round: a real
    /// transport blocks on the socket until the deadline before it
    /// deregisters a straggler, and this wait is exactly the window
    /// the engine's speculative aggregation overlaps with server-side
    /// work. Which clients end up missing is decided by the schedule
    /// alone; only the reporting instant is wall-clock.
    fn flush_late_certs(&mut self) {
        let Some(latest) =
            self.late_certs.iter().map(|&(_, t)| t).max()
        else {
            return;
        };
        let now = Instant::now();
        if latest > now {
            std::thread::sleep(latest - now);
        }
        for (c, _) in self.late_certs.drain(..) {
            self.missing.push(c);
        }
    }
}

impl<P: ClientPool> ClientPool for FaultPool<P> {
    fn n_clients(&self) -> usize {
        self.inner.n_clients()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn family(&self) -> ClientFamily {
        self.inner.family()
    }

    fn kind_name(&self) -> &'static str {
        self.inner.kind_name()
    }

    fn default_alpha(&self) -> f64 {
        self.inner.default_alpha()
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        self.inner.set_alpha(alpha)
    }

    fn prepare_round(&mut self, round: u64) {
        self.inner.prepare_round(round);
        for (c, was_dead) in self.dead.iter_mut().enumerate() {
            let now_dead = self.plan.dead_at(c as u32, round);
            if *was_dead && !now_dead {
                self.rejoined.push(c as u32);
            }
            *was_dead = now_dead;
        }
    }

    fn dead_clients(&self) -> Vec<u32> {
        let mut out = self.inner.dead_clients();
        for (c, dead) in self.dead.iter().enumerate() {
            if *dead && !out.contains(&(c as u32)) {
                out.push(c as u32);
            }
        }
        out.sort_unstable();
        out
    }

    fn take_missing(&mut self) -> Vec<u32> {
        self.missing.extend(self.inner.take_missing());
        std::mem::take(&mut self.missing)
    }

    fn take_rejoined(&mut self) -> Vec<u32> {
        self.rejoined.extend(self.inner.take_rejoined());
        // A natively-killed partition is reported twice — by the
        // desugared plan spans *and* by the transport's adoption path;
        // dedup (sorted: deterministic order on every transport).
        self.rejoined.sort_unstable();
        self.rejoined.dedup();
        std::mem::take(&mut self.rejoined)
    }

    fn take_fresh_rejoined(&mut self) -> Vec<u32> {
        self.inner.take_fresh_rejoined()
    }

    fn ack_round(&mut self, round: u64, committed: &[u32]) {
        self.inner.ack_round(round, committed);
    }

    fn resolve_staged(&mut self, client: u32, last_commit: Option<u64>) {
        self.inner.resolve_staged(client, last_commit);
    }

    fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
        self.inner.pull_h_packed()
    }

    fn supports_shard_kill(&self) -> bool {
        self.inner.supports_shard_kill()
    }

    fn kill_shard(&mut self, shard: u32) {
        self.inner.kill_shard(shard);
    }

    fn shard_ranges(&self) -> Option<Vec<(u32, u32)>> {
        self.inner.shard_ranges()
    }

    fn take_master_kill(&mut self, round: u64) -> bool {
        // Pure schedule lookup (the engine asks exactly once per
        // round), so the injection is idempotent across the very
        // reconstruction it triggers.
        self.plan.master_kills.contains(&round)
    }

    fn set_reply_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
        self.inner.set_reply_deadline(deadline);
    }

    fn submit_round(&mut self, x: &[f64], subset: Option<&[u32]>, round: u64, need_loss: bool) {
        // Scripted relay deaths land here, before the round goes out:
        // the partition is already suppressed below (its desugared
        // kill spans), severing the channel now makes the real
        // failover — client reconnection, partition adoption — run
        // inside exactly the round the schedule names.
        for nk in &mut self.native_kills {
            if nk.0 == round && !nk.2 {
                self.inner.kill_shard(nk.1);
                nk.2 = true;
            }
        }
        let all: Vec<u32>;
        let participants: &[u32] = match subset {
            Some(s) => s,
            None => {
                all = (0..self.n_clients() as u32).collect();
                &all
            }
        };
        self.holds.clear();
        self.late_certs.clear();
        let mut live = Vec::with_capacity(participants.len());
        for &ci in participants {
            if self.plan.dead_at(ci, round) || self.plan.dropped_at(ci, round) {
                self.missing.push(ci);
                continue;
            }
            if let Some(ms) = self.plan.effective_delay_at(ci, round) {
                if self.delay_becomes_drop(ms) {
                    let dl = self.deadline.unwrap();
                    self.late_certs.push((ci, Instant::now() + dl));
                    continue;
                }
                self.holds.push((ci, Instant::now() + Duration::from_millis(ms)));
            }
            live.push(ci);
        }
        // Corruptions scheduled for this round against live repliers;
        // mutation happens in drain(), on the master side, before the
        // engine (or the sum fold below) ever sees the reply.
        self.corrupt_now = self
            .plan
            .corruptions
            .iter()
            .filter(|&&(r, c, _)| r == round && live.contains(&c))
            .map(|&(_, c, m)| (c, m))
            .collect();
        self.corrupt_round = round;
        // Rounds with injected stragglers need the atoms (each held
        // reply is released individually), and so do corruption
        // rounds (the mutation targets one client's reply); every
        // other round forwards the engine's mode so shard tiers keep
        // pre-reducing.
        self.round_atoms = self.mode == RoundMode::Atoms
            || !self.holds.is_empty()
            || !self.corrupt_now.is_empty();
        self.inner.set_round_mode(if self.round_atoms {
            RoundMode::Atoms
        } else {
            RoundMode::Sums
        });
        self.inner.submit_round(x, Some(&live), round, need_loss);
    }

    fn set_round_mode(&mut self, mode: RoundMode) {
        self.mode = mode;
    }

    fn drain_sums(&mut self) -> Vec<RoundSum> {
        if !self.round_atoms {
            let out = self.inner.drain_sums();
            if out.is_empty() {
                self.flush_late_certs();
            }
            return out;
        }
        // Atom fallback (delay holds or corruptions in flight):
        // enforce the holds and apply the scheduled corruptions, then
        // fold — bit-identical to the pre-reduced path (and on a
        // corruption round the fold happens *after* the mutation, so
        // sum-mode callers see exactly the Byzantine inputs).
        let batch = self.drain();
        if batch.is_empty() {
            return Vec::new();
        }
        vec![RoundSum::from_msgs(&batch)]
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        let mut out = self.inner.drain();
        if out.is_empty() {
            // No further replies this round: serve the detection
            // latency of any over-deadline stragglers before the
            // engine's closing `take_missing` pass.
            self.flush_late_certs();
            return out;
        }
        // Enforce injected straggler delays: hold each delayed reply
        // until its release instant. Wall-clock only — the commit order
        // and trajectory are unaffected.
        for m in &out {
            let pos = self.holds.iter().position(|&(c, _)| c as usize == m.client_id);
            if let Some(pos) = pos {
                let (_, release) = self.holds.swap_remove(pos);
                let now = Instant::now();
                if release > now {
                    std::thread::sleep(release - now);
                }
            }
        }
        // Byzantine mutation: every reply passes through this return
        // path exactly once (held replies included), so each scheduled
        // corruption lands exactly once; duplicate (round, client)
        // events compose in plan order.
        if !self.corrupt_now.is_empty() {
            for m in &mut out {
                for &(c, mode) in &self.corrupt_now {
                    if c as usize == m.client_id {
                        corrupt_msg(m, mode, self.corrupt_round, c);
                    }
                }
            }
        }
        out
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        self.inner.eval_loss_each(x)
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        self.inner.loss_grad_each(x)
    }

    fn loss_grad_sum(&mut self, x: &[f64]) -> (RepAcc, RepVec, u32) {
        // Delegate so the inner tier's pre-reduction (sharded/relay)
        // is not lost behind the fault wrapper; the probe itself is
        // measurement-only and exempt from injection.
        self.inner.loss_grad_sum(x)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.inner.warm_start(x)
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.inner.init_state()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        self.inner.pull_state(client)
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        self.inner.transport_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_schema() {
        let plan = FaultPlan::parse(
            "kill@6:1-18, delay@3:2:25, drop@12:0, kill@4:3, killrelay@5:1",
        )
        .unwrap();
        assert_eq!(plan.kills.len(), 2);
        assert_eq!(plan.kills[0].client, 1);
        assert_eq!(plan.kills[0].from, 6);
        assert_eq!(plan.kills[0].until, Some(18));
        assert_eq!(plan.kills[1].until, None);
        assert_eq!(plan.drops, vec![(12, 0)]);
        assert_eq!(plan.delays, vec![(3, 2, 25)]);
        assert_eq!(plan.relay_kills, vec![(5, 1)]);
    }

    #[test]
    fn killrelay_parses_and_round_trips() {
        let plan = FaultPlan::parse("killrelay@4:0,killrelay@7:2").unwrap();
        assert_eq!(plan.relay_kills, vec![(4, 0), (7, 2)]);
        assert!(!plan.is_empty());
        let re = FaultPlan::parse(&plan.to_spec()).unwrap();
        assert_eq!(plan, re);
        // Builder ≡ parser.
        let built =
            FaultPlan::none().with_relay_kill(4, 0).with_relay_kill(7, 2);
        assert_eq!(built, plan);
    }

    #[test]
    fn corrupt_parses_and_round_trips() {
        let spec = "corrupt@2:1:scale:100,corrupt@3:0:signflip,\
                    corrupt@4:2:garbage,corrupt@5:3:zero,\
                    corrupt@6:1:scale:-0.5";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(
            plan.corruptions,
            vec![
                (2, 1, CorruptMode::Scale(100.0)),
                (3, 0, CorruptMode::SignFlip),
                (4, 2, CorruptMode::Garbage),
                (5, 3, CorruptMode::Zero),
                (6, 1, CorruptMode::Scale(-0.5)),
            ]
        );
        assert!(!plan.is_empty());
        let re = FaultPlan::parse(&plan.to_spec()).unwrap();
        assert_eq!(plan, re);
        // Builder ≡ parser.
        let built = FaultPlan::none()
            .with_corrupt(2, 1, CorruptMode::Scale(100.0))
            .with_corrupt(3, 0, CorruptMode::SignFlip)
            .with_corrupt(4, 2, CorruptMode::Garbage)
            .with_corrupt(5, 3, CorruptMode::Zero)
            .with_corrupt(6, 1, CorruptMode::Scale(-0.5));
        assert_eq!(built, plan);
        // Non-integer K round-trips bit-exactly through the shortest
        // f64 Display form.
        let p = FaultPlan::parse("corrupt@1:2:scale:0.1").unwrap();
        assert_eq!(FaultPlan::parse(&p.to_spec()).unwrap(), p);
    }

    #[test]
    fn corrupt_rejects_malformed() {
        // Bad K.
        assert!(FaultPlan::parse("corrupt@1:2:scale:abc").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:scale:").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:scale").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:scale:1x").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:scale:inf").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:scale:NaN").is_err());
        // Unknown mode.
        assert!(FaultPlan::parse("corrupt@1:2:boom").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:").is_err());
        // Junk suffixes on argument-free modes.
        assert!(FaultPlan::parse("corrupt@1:2:zerox").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:zero:5").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:signflip:1").is_err());
        assert!(FaultPlan::parse("corrupt@1:2:garbagey").is_err());
        // Missing fields / negative ids.
        assert!(FaultPlan::parse("corrupt@1:2").is_err());
        assert!(FaultPlan::parse("corrupt@1").is_err());
        assert!(FaultPlan::parse("corrupt@1:-2:zero").is_err());
        assert!(FaultPlan::parse("corrupt@-1:2:zero").is_err());
    }

    #[test]
    fn corrupt_injects_deterministically_on_flat_pool() {
        use crate::algorithms::ClientState;
        use crate::compressors::Identity;
        use crate::linalg::Mat;
        use crate::oracle::QuadraticOracle;
        let mk_clients = || -> Vec<ClientState> {
            (0..4)
                .map(|i| {
                    let q =
                        Mat::from_rows(&[&[2.0, 0.5], &[0.5, 1.5]]);
                    ClientState::new(
                        i,
                        Box::new(QuadraticOracle::new(
                            q,
                            vec![1.0, -1.0],
                        )),
                        Box::new(Identity),
                        None,
                    )
                })
                .collect()
        };
        let plan = FaultPlan::parse(
            "corrupt@1:0:garbage,corrupt@1:1:scale:100,\
             corrupt@1:2:zero,corrupt@1:3:signflip",
        )
        .unwrap();
        let drain_all = |fp: &mut FaultPool<_>| {
            let mut got: Vec<ClientMsg> = Vec::new();
            loop {
                let b = fp.drain();
                if b.is_empty() {
                    break;
                }
                got.extend(b);
            }
            got.sort_by_key(|m| m.client_id);
            got
        };
        let run = |p: FaultPlan| {
            let mut fp = FaultPool::new(
                super::super::SeqPool::new(mk_clients()),
                p,
            );
            let x = [0.3, -0.2];
            let mut r1 = Vec::new();
            for round in 0..2u64 {
                fp.prepare_round(round);
                fp.submit_round(&x, None, round, false);
                r1 = drain_all(&mut fp);
            }
            r1
        };
        // Honest reference: the same clients under the empty plan.
        // Client-side state evolves identically (corruption is master-
        // side only), so its round-1 batch is exactly what the
        // corrupted run's replies looked like before mutation.
        let clean = run(FaultPlan::none());
        let dirty = run(plan.clone());
        assert_eq!(clean.len(), 4);
        assert_eq!(dirty.len(), 4);
        // garbage: differs from honest and is non-zero.
        assert_ne!(dirty[0].grad, clean[0].grad);
        assert!(dirty[0].grad.iter().any(|&g| g != 0.0));
        // scale:100 multiplies the gradient exactly.
        for (c, d) in clean[1].grad.iter().zip(&dirty[1].grad) {
            assert_eq!(d.to_bits(), (c * 100.0).to_bits());
        }
        assert_eq!(dirty[1].update.scale, clean[1].update.scale * 100.0);
        // zero blanks the gradient and neutralizes the update scale.
        assert!(dirty[2].grad.iter().all(|&g| g == 0.0));
        assert_eq!(dirty[2].update.scale, 0.0);
        assert_eq!(dirty[2].update.values, clean[2].update.values);
        // signflip negates exactly.
        for (c, d) in clean[3].grad.iter().zip(&dirty[3].grad) {
            assert_eq!(d.to_bits(), (-c).to_bits());
        }
        // Pure function of (plan, round): a second run reproduces the
        // corrupted batch bit-for-bit, garbage payload included.
        let dirty2 = run(plan);
        for (a, b) in dirty.iter().zip(&dirty2) {
            assert_eq!(a.client_id, b.client_id);
            let bits = |v: &[f64]| -> Vec<u64> {
                v.iter().map(|x| x.to_bits()).collect()
            };
            assert_eq!(bits(&a.grad), bits(&b.grad));
            assert_eq!(bits(&a.update.values), bits(&b.update.values));
            assert_eq!(
                a.update.scale.to_bits(),
                b.update.scale.to_bits()
            );
        }
    }

    #[test]
    fn killrelay_rejects_malformed() {
        assert!(FaultPlan::parse("killrelay@1:-2").is_err()); // neg shard
        assert!(FaultPlan::parse("killrelay@-1:2").is_err()); // neg round
        assert!(FaultPlan::parse("killrelay@1:2x").is_err()); // junk
        assert!(FaultPlan::parse("killrelay@x:2").is_err());
        assert!(FaultPlan::parse("killrelay@1.5:2").is_err());
        assert!(FaultPlan::parse("killrelay@1:2-3").is_err()); // no spans
        assert!(FaultPlan::parse("killrelay@5").is_err()); // missing :S
    }

    #[test]
    fn killrelay_desugars_to_partition_kill_spans() {
        let mut plan = FaultPlan::parse("killrelay@2:1").unwrap();
        plan.desugar_relay_kills(&[(0, 2), (2, 5)]);
        // Shard 1's range [2, 5): each client frozen exactly round 2.
        assert_eq!(
            plan.kills,
            vec![
                KillSpan { client: 2, from: 2, until: Some(3) },
                KillSpan { client: 3, from: 2, until: Some(3) },
                KillSpan { client: 4, from: 2, until: Some(3) },
            ]
        );
        // The relay event survives desugaring (the native trigger).
        assert_eq!(plan.relay_kills, vec![(2, 1)]);
        for c in 2..5u32 {
            assert!(plan.dead_at(c, 2));
            assert!(!plan.dead_at(c, 1) && !plan.dead_at(c, 3));
        }
        assert!(!plan.dead_at(0, 2) && !plan.dead_at(1, 2));
    }

    #[test]
    #[should_panic(expected = "has 2 shards")]
    fn killrelay_bad_shard_id_panics_at_desugar() {
        let mut plan = FaultPlan::parse("killrelay@1:5").unwrap();
        plan.desugar_relay_kills(&[(0, 2), (2, 4)]);
    }

    #[test]
    fn parse_rejects_malformed_events() {
        assert!(FaultPlan::parse("boom@1:2").is_err());
        assert!(FaultPlan::parse("kill@x:2").is_err());
        assert!(FaultPlan::parse("kill@5:2-3").is_err()); // rejoin <= kill
        assert!(FaultPlan::parse("delay@1:2").is_err()); // missing ms
        assert!(FaultPlan::parse("drop12:0").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_bad_ranges() {
        // Degenerate and inverted kill windows.
        assert!(FaultPlan::parse("kill@5:2-5").is_err()); // rejoin == kill
        assert!(FaultPlan::parse("kill@9:0-3").is_err()); // rejoin < kill
        // Well-formed boundary: rejoin exactly one round later is fine.
        let p = FaultPlan::parse("kill@5:2-6").unwrap();
        assert_eq!(p.kills[0].until, Some(6));
        assert!(p.dead_at(2, 5) && !p.dead_at(2, 6));
    }

    #[test]
    fn parse_rejects_negative_ids() {
        // All fields are unsigned on the wire and in the schema; a
        // leading minus must be a parse error, never a wrap-around.
        assert!(FaultPlan::parse("kill@1:-2").is_err());
        assert!(FaultPlan::parse("kill@-1:2").is_err());
        assert!(FaultPlan::parse("drop@3:-1").is_err());
        assert!(FaultPlan::parse("delay@2:-4:10").is_err());
        assert!(FaultPlan::parse("delay@2:4:-10").is_err());
    }

    #[test]
    fn parse_rejects_junk_suffixes() {
        assert!(FaultPlan::parse("drop@1:2x").is_err());
        assert!(FaultPlan::parse("kill@1:2-3junk").is_err());
        assert!(FaultPlan::parse("delay@1:2:3ms").is_err());
        assert!(FaultPlan::parse("kill@1.5:2").is_err()); // float round
        assert!(FaultPlan::parse("delay@1:2:3:4").is_err()); // extra field
        // Stray separators around well-formed events stay accepted
        // (empty segments are skipped), junk inside them is not.
        assert!(FaultPlan::parse("drop@1:2,,").is_ok());
        assert!(FaultPlan::parse("drop@1:2, drop@2:x").is_err());
    }

    #[test]
    fn spec_round_trips_through_parser() {
        let specs = [
            "kill@6:1-18,drop@12:0,delay@3:2:25",
            "kill@4:3",
            "kill@0:0-1,kill@2:1,drop@0:0,drop@9:7,delay@1:0:0",
            "",
        ];
        for spec in specs {
            let plan = FaultPlan::parse(spec).unwrap();
            let re = FaultPlan::parse(&plan.to_spec()).unwrap();
            assert_eq!(plan, re, "spec '{spec}' did not round-trip");
        }
        // And builder → spec → parse reproduces the builder exactly.
        let built = FaultPlan::none()
            .with_kill(7, 1, None)
            .with_kill(0, 3, Some(9))
            .with_drop(2, 5)
            .with_delay(4, 6, 125);
        assert_eq!(FaultPlan::parse(&built.to_spec()).unwrap(), built);
    }

    #[test]
    fn parse_matches_builder() {
        let parsed = FaultPlan::parse("kill@2:0-5,drop@1:3,delay@4:2:30").unwrap();
        let built = FaultPlan::none()
            .with_kill(0, 2, Some(5))
            .with_drop(1, 3)
            .with_delay(4, 2, 30);
        assert_eq!(parsed, built);
    }

    #[test]
    fn dead_at_spans() {
        let plan = FaultPlan::none().with_kill(1, 3, Some(6)).with_kill(2, 4, None);
        assert!(!plan.dead_at(1, 2));
        assert!(plan.dead_at(1, 3));
        assert!(plan.dead_at(1, 5));
        assert!(!plan.dead_at(1, 6));
        assert!(plan.dead_at(2, 100));
        assert!(!plan.dead_at(0, 3));
    }

    #[test]
    fn killrelay_on_flat_pool_freezes_partition_for_one_round() {
        use crate::algorithms::ClientState;
        use crate::compressors::Identity;
        use crate::linalg::Mat;
        use crate::oracle::QuadraticOracle;
        let clients: Vec<ClientState> = (0..4)
            .map(|i| {
                let q = Mat::from_rows(&[&[2.0, 0.5], &[0.5, 1.5]]);
                ClientState::new(
                    i,
                    Box::new(QuadraticOracle::new(q, vec![1.0, -1.0])),
                    Box::new(Identity),
                    None,
                )
            })
            .collect();
        let pool = super::super::SeqPool::new(clients);
        let plan = FaultPlan::parse("killrelay@1:1").unwrap();
        // Flat transport: the explicit layout desugars the relay kill.
        let mut fp = FaultPool::with_shard_layout(pool, plan, 2);
        let drain_all = |fp: &mut FaultPool<_>| {
            let mut got = Vec::new();
            loop {
                let b = fp.drain();
                if b.is_empty() {
                    break;
                }
                got.extend(b.into_iter().map(|m| m.client_id as u32));
            }
            got
        };
        // Round 0: everyone lives.
        fp.prepare_round(0);
        assert!(fp.take_rejoined().is_empty());
        fp.submit_round(&[0.0, 0.0], None, 0, false);
        assert_eq!(drain_all(&mut fp).len(), 4);
        assert!(fp.take_missing().is_empty());
        // Round 1: shard 1's partition (clients 2, 3) is dead.
        fp.prepare_round(1);
        assert_eq!(fp.dead_clients(), vec![2, 3]);
        fp.submit_round(&[0.0, 0.0], None, 1, false);
        let mut committed = drain_all(&mut fp);
        committed.sort_unstable();
        assert_eq!(committed, vec![0, 1]);
        let mut missing = fp.take_missing();
        missing.sort_unstable();
        assert_eq!(missing, vec![2, 3]);
        // Round 2: the partition is adopted/rejoined.
        fp.prepare_round(2);
        assert_eq!(fp.take_rejoined(), vec![2, 3]);
        assert!(fp.dead_clients().is_empty());
        fp.submit_round(&[0.0, 0.0], None, 2, false);
        assert_eq!(drain_all(&mut fp).len(), 4);
    }

    #[test]
    fn delay_beyond_deadline_is_a_drop() {
        // Pure schedule arithmetic — no pool needed beyond a stub.
        let plan = FaultPlan::none().with_delay(0, 0, 500);
        assert_eq!(plan.delay_at(0, 0), Some(500));
        assert_eq!(plan.delay_at(0, 1), None);
        assert_eq!(plan.delay_at(1, 0), None);
    }

    #[test]
    fn delaydist_parses_and_round_trips() {
        let plan =
            FaultPlan::parse("delaydist@2-6:lognormal:3.5:0.75").unwrap();
        assert_eq!(plan.delay_dists, vec![(2, 6, 3.5, 0.75)]);
        assert!(!plan.is_empty());
        let re = FaultPlan::parse(&plan.to_spec()).unwrap();
        assert_eq!(plan, re);
        // Builder ≡ parser, and non-integer params round-trip
        // bit-exactly through the shortest f64 Display form.
        let built = FaultPlan::none().with_delay_dist(2, 6, 3.5, 0.75);
        assert_eq!(built, plan);
        let p =
            FaultPlan::parse("delaydist@0-3:lognormal:-0.1:0.3").unwrap();
        assert_eq!(FaultPlan::parse(&p.to_spec()).unwrap(), p);
    }

    #[test]
    fn delaydist_rejects_malformed() {
        // Bad / missing round spans.
        assert!(FaultPlan::parse("delaydist@2:lognormal:1:1").is_err());
        assert!(FaultPlan::parse("delaydist@5-5:lognormal:1:1").is_err());
        assert!(FaultPlan::parse("delaydist@6-2:lognormal:1:1").is_err());
        assert!(FaultPlan::parse("delaydist@x-2:lognormal:1:1").is_err());
        assert!(FaultPlan::parse("delaydist@1--2:lognormal:1:1").is_err());
        // Unknown distribution / missing params.
        assert!(FaultPlan::parse("delaydist@1-2:uniform:1:1").is_err());
        assert!(FaultPlan::parse("delaydist@1-2:lognormal:1").is_err());
        assert!(FaultPlan::parse("delaydist@1-2:lognormal").is_err());
        assert!(FaultPlan::parse("delaydist@1-2").is_err());
        // Non-finite / negative-sigma parameters.
        assert!(FaultPlan::parse("delaydist@1-2:lognormal:inf:1").is_err());
        assert!(FaultPlan::parse("delaydist@1-2:lognormal:1:NaN").is_err());
        assert!(FaultPlan::parse("delaydist@1-2:lognormal:1:-0.5").is_err());
        assert!(FaultPlan::parse("delaydist@1-2:lognormal:1:1x").is_err());
        // Extra trailing field.
        assert!(FaultPlan::parse("delaydist@1-2:lognormal:1:1:9").is_err());
    }

    #[test]
    fn delaydist_draws_are_seeded_and_windowed() {
        let plan = FaultPlan::none().with_delay_dist(3, 5, 4.0, 0.5);
        // Outside the window: no draw.
        assert_eq!(plan.dist_delay_at(0, 2), None);
        assert_eq!(plan.dist_delay_at(0, 5), None);
        // Inside: deterministic (pure in (round, client))...
        let d = plan.dist_delay_at(1, 3).unwrap();
        assert_eq!(plan.dist_delay_at(1, 3), Some(d));
        // ...and varying across clients and rounds (lognormal with
        // sigma > 0 — three equal draws would mean broken seeding).
        let draws = [
            plan.dist_delay_at(0, 3).unwrap(),
            plan.dist_delay_at(1, 3).unwrap(),
            plan.dist_delay_at(0, 4).unwrap(),
        ];
        assert!(
            draws[0] != draws[1] || draws[1] != draws[2],
            "all draws equal: {draws:?}"
        );
        // A scripted delay on the same (round, client) wins.
        let plan = plan.with_delay(3, 1, 7);
        assert_eq!(plan.effective_delay_at(1, 3), Some(7));
        assert_eq!(plan.effective_delay_at(0, 3), Some(draws[0]));
    }

    #[test]
    fn killmaster_parses_and_round_trips() {
        let plan = FaultPlan::parse("killmaster@4,killmaster@9").unwrap();
        assert_eq!(plan.master_kills, vec![4, 9]);
        assert!(!plan.is_empty());
        let re = FaultPlan::parse(&plan.to_spec()).unwrap();
        assert_eq!(plan, re);
        let built = FaultPlan::none().with_master_kill(4).with_master_kill(9);
        assert_eq!(built, plan);
        // Composes with client-facing events in one spec.
        let plan = FaultPlan::parse(
            "kill@2:1-4,killmaster@3,corrupt@5:0:zero",
        )
        .unwrap();
        assert_eq!(plan.master_kills, vec![3]);
        assert_eq!(plan.kills.len(), 1);
        assert_eq!(plan.corruptions.len(), 1);
        assert_eq!(FaultPlan::parse(&plan.to_spec()).unwrap(), plan);
    }

    #[test]
    fn killmaster_rejects_malformed() {
        assert!(FaultPlan::parse("killmaster@").is_err());
        assert!(FaultPlan::parse("killmaster@x").is_err());
        assert!(FaultPlan::parse("killmaster@-3").is_err());
        assert!(FaultPlan::parse("killmaster@1.5").is_err());
        // The old generic error path must not swallow a client field.
        assert!(FaultPlan::parse("killmaster@3:1").is_err());
        assert!(FaultPlan::parse("killmaster3").is_err());
    }

    #[test]
    fn prime_liveness_restores_rejoin_detection() {
        use crate::algorithms::ClientState;
        use crate::compressors::Identity;
        use crate::linalg::Mat;
        use crate::oracle::QuadraticOracle;
        let clients: Vec<ClientState> = (0..3)
            .map(|i| {
                let q = Mat::from_rows(&[&[2.0, 0.5], &[0.5, 1.5]]);
                ClientState::new(
                    i,
                    Box::new(QuadraticOracle::new(q, vec![1.0, -1.0])),
                    Box::new(Identity),
                    None,
                )
            })
            .collect();
        // Client 1 frozen rounds [2, 5): a master restored at round 5
        // must report its thaw even though the wrapper never saw
        // rounds 2..5.
        let plan = FaultPlan::none().with_kill(1, 2, Some(5));
        let mut fp =
            FaultPool::new(super::super::SeqPool::new(clients), plan);
        fp.prime_liveness(5);
        fp.prepare_round(5);
        assert_eq!(fp.take_rejoined(), vec![1]);
        // Idempotent on the engine's schedule: the kill round itself
        // is looked up, not consumed.
        assert!(!ClientPool::take_master_kill(&mut fp, 5));
    }
}

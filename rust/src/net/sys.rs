//! Self-contained readiness syscalls for the event-driven transport.
//!
//! The repo's no-heavy-deps rule (paper §7: "any unnecessary
//! abstractions ... take resources and are not free") extends to the
//! event loop: no `tokio`, no `mio`, not even the `libc` crate. The
//! handful of symbols the readiness loop needs — `poll(2)`, and on
//! Linux the `epoll(7)` family — are declared directly against the C
//! library every Rust binary on these platforms already links.
//!
//! Two things are exposed:
//!
//! * [`wait_writable`] — park until a socket accepts more bytes (the
//!   blocking write path's `WouldBlock` recovery in `framing`);
//! * [`Poller`] — a level-triggered readiness multiplexer over many
//!   sockets: `epoll` on Linux (one O(ready) wait regardless of the
//!   registered-fd count — the 100k-client requirement), `poll` on
//!   other unixes (O(fds) per wait, fine for the handful of mux
//!   sockets the fallback actually sees). Non-unix builds get the
//!   blocking transports only.

use std::io;
use std::net::TcpStream;
use std::time::Duration;

#[cfg(unix)]
use std::os::unix::io::{AsRawFd, RawFd};

// --- poll(2): POSIX, used by wait_writable and the non-Linux Poller --

#[cfg(all(unix, not(target_os = "linux")))]
const POLLIN: i16 = 0x001;
#[cfg(unix)]
const POLLOUT: i16 = 0x004;

#[cfg(unix)]
#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

// `nfds_t` is `unsigned long` on Linux, `unsigned int` elsewhere.
#[cfg(target_os = "linux")]
type NfdsT = std::os::raw::c_ulong;
#[cfg(all(unix, not(target_os = "linux")))]
type NfdsT = std::os::raw::c_uint;

#[cfg(unix)]
extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout_ms: i32) -> i32;
}

/// Block until `stream` is writable again (POLLOUT — or an
/// error/hangup condition, which the caller's next `write` surfaces as
/// a real error). Used to resume a frame write that hit `WouldBlock`.
pub fn wait_writable(stream: &mut TcpStream) -> io::Result<()> {
    #[cfg(unix)]
    {
        let mut pfd = PollFd {
            fd: stream.as_raw_fd(),
            events: POLLOUT,
            revents: 0,
        };
        loop {
            let rc = unsafe { poll(&mut pfd, 1, -1) };
            if rc >= 0 {
                return Ok(());
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
    #[cfg(not(unix))]
    {
        // Portable fallback: brief backoff, let the write loop retry.
        let _ = stream;
        std::thread::sleep(Duration::from_millis(1));
        Ok(())
    }
}

/// One readiness report from [`Poller::wait`]. Error/hangup conditions
/// are folded into `readable` (the next read returns `Ok(0)`/`Err`,
/// which is where the connection retirement logic already lives).
#[cfg(unix)]
#[derive(Debug, Clone, Copy)]
pub struct Ready {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Clamp an optional wait budget to the millisecond argument the
/// kernel interfaces take: `None` = infinite (-1); sub-millisecond
/// remainders round **up** so a nearly-expired deadline does not spin.
#[cfg(unix)]
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms.min(i32::MAX as u128) as i32
            }
        }
    }
}

// --- Linux: epoll -----------------------------------------------------

#[cfg(target_os = "linux")]
mod imp {
    use super::{timeout_ms, Ready};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    // Kernel ABI: epoll_event is packed on x86_64 (and only there).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(
            epfd: i32,
            op: i32,
            fd: i32,
            event: *mut EpollEvent,
        ) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    fn interest(readable: bool, writable: bool) -> u32 {
        let mut ev = EPOLLRDHUP;
        if readable {
            ev |= EPOLLIN;
        }
        if writable {
            ev |= EPOLLOUT;
        }
        ev
    }

    /// Level-triggered epoll instance; the whole event loop runs on
    /// the master thread, so no wakers or cross-thread arming needed.
    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(
            &self,
            op: i32,
            fd: RawFd,
            events: u32,
            token: u64,
        ) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(
                EPOLL_CTL_ADD,
                fd,
                interest(readable, writable),
                token,
            )
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(
                EPOLL_CTL_MOD,
                fd,
                interest(readable, writable),
                token,
            )
        }

        pub fn deregister(&mut self, fd: RawFd) {
            // Best-effort: the fd may already be closed (EBADF), which
            // deregisters implicitly.
            let _ = self.ctl(EPOLL_CTL_DEL, fd, 0, 0);
        }

        /// One kernel wait; appends readiness reports to `out`.
        /// Returns the number of reports (0 = timeout expired).
        pub fn wait(
            &mut self,
            out: &mut Vec<Ready>,
            timeout: Option<Duration>,
        ) -> io::Result<usize> {
            let ms = timeout_ms(timeout);
            let n = loop {
                let rc = unsafe {
                    epoll_wait(
                        self.epfd,
                        self.buf.as_mut_ptr(),
                        self.buf.len() as i32,
                        ms,
                    )
                };
                if rc >= 0 {
                    break rc as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            for ev in &self.buf[..n] {
                let bits = ev.events;
                let hup = bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0;
                out.push(Ready {
                    token: ev.data,
                    readable: bits & EPOLLIN != 0 || hup,
                    writable: bits & EPOLLOUT != 0,
                });
            }
            Ok(n)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

// --- other unixes: poll(2) over the registered set --------------------

#[cfg(all(unix, not(target_os = "linux")))]
mod imp {
    use super::{timeout_ms, PollFd, Ready, POLLIN, POLLOUT};
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    /// `poll(2)`-based fallback: rebuilds the pollfd array per wait
    /// (O(fds)) — acceptable at the fallback's scale; Linux (CI and
    /// the paper's testbed) takes the epoll path above.
    pub struct Poller {
        fds: Vec<(RawFd, u64, bool, bool)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Self> {
            Ok(Self { fds: Vec::new() })
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.fds.push((fd, token, readable, writable));
            Ok(())
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            match self.fds.iter_mut().find(|e| e.0 == fd) {
                Some(e) => {
                    *e = (fd, token, readable, writable);
                    Ok(())
                }
                None => self.register(fd, token, readable, writable),
            }
        }

        pub fn deregister(&mut self, fd: RawFd) {
            self.fds.retain(|e| e.0 != fd);
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<Ready>,
            timeout: Option<Duration>,
        ) -> io::Result<usize> {
            let mut pfds: Vec<PollFd> = self
                .fds
                .iter()
                .map(|&(fd, _, r, w)| PollFd {
                    fd,
                    events: (if r { POLLIN } else { 0 })
                        | (if w { POLLOUT } else { 0 }),
                    revents: 0,
                })
                .collect();
            let ms = timeout_ms(timeout);
            loop {
                let rc = unsafe {
                    super::poll(
                        pfds.as_mut_ptr(),
                        pfds.len() as super::NfdsT,
                        ms,
                    )
                };
                if rc >= 0 {
                    break;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            }
            let mut n = 0;
            for (pfd, &(_, token, _, _)) in
                pfds.iter().zip(self.fds.iter())
            {
                if pfd.revents != 0 {
                    out.push(Ready {
                        token,
                        // POLLERR/POLLHUP/POLLNVAL fold into readable.
                        readable: pfd.revents & !POLLOUT != 0,
                        writable: pfd.revents & POLLOUT != 0,
                    });
                    n += 1;
                }
            }
            Ok(n)
        }
    }
}

#[cfg(unix)]
pub use imp::Poller;

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[cfg(unix)]
    #[test]
    fn poller_reports_read_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        rx.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.register(rx.as_raw_fd(), 7, true, false).unwrap();

        // Nothing to read yet: a bounded wait times out empty.
        let mut out = Vec::new();
        let n = poller
            .wait(&mut out, Some(Duration::from_millis(10)))
            .unwrap();
        assert_eq!(n, 0);

        tx.write_all(b"ping").unwrap();
        let n = poller
            .wait(&mut out, Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(n, 1);
        assert_eq!(out[0].token, 7);
        assert!(out[0].readable);

        let mut buf = [0u8; 8];
        let mut rx = rx;
        assert_eq!(rx.read(&mut buf).unwrap(), 4);
        poller.deregister(rx.as_raw_fd());
    }

    #[cfg(unix)]
    #[test]
    fn poller_reports_write_readiness_and_rearm() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (_rx, _) = listener.accept().unwrap();
        tx.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.register(tx.as_raw_fd(), 1, false, true).unwrap();
        let mut out = Vec::new();
        let n = poller
            .wait(&mut out, Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(n, 1);
        assert!(out[0].writable);

        // Re-arm to read-only interest: an idle socket reports nothing.
        poller.reregister(tx.as_raw_fd(), 1, true, false).unwrap();
        out.clear();
        let n = poller
            .wait(&mut out, Some(Duration::from_millis(10)))
            .unwrap();
        assert_eq!(n, 0);
    }
}

//! Symmetric quadratic objective f(x) = ½ xᵀQx − bᵀx (paper ships
//! "logistic regression and Symmetric Quadratic Objectives" out of the
//! box, Appendix L.5). Closed-form optimum x* = Q⁻¹b makes it the ideal
//! convergence test fixture: FedNL must reach x* superlinearly, and for
//! the Identity compressor the very first Newton step is exact.

use super::Oracle;
use crate::linalg::{vector, Mat};

/// ½ xᵀQx − bᵀx with SPD Q.
#[derive(Debug, Clone)]
pub struct QuadraticOracle {
    q: Mat,
    b: Vec<f64>,
}

impl QuadraticOracle {
    pub fn new(q: Mat, b: Vec<f64>) -> Self {
        assert_eq!(q.rows(), q.cols());
        assert_eq!(q.rows(), b.len());
        Self { q, b }
    }

    /// The exact minimizer Q⁻¹ b (via Cholesky).
    pub fn solution(&self) -> Option<Vec<f64>> {
        crate::linalg::cholesky::solve_spd(&self.q, 0.0, &self.b)
    }
}

impl Oracle for QuadraticOracle {
    fn dim(&self) -> usize {
        self.b.len()
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        let mut qx = vec![0.0; x.len()];
        self.q.matvec(x, &mut qx);
        0.5 * vector::dot(x, &qx) - vector::dot(&self.b, x)
    }

    fn loss_grad(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
        self.q.matvec(x, g); // g = Qx
        let half_quad = 0.5 * vector::dot(x, g);
        let lin = vector::dot(&self.b, x);
        vector::axpy(-1.0, &self.b, g); // g = Qx − b
        half_quad - lin
    }

    fn loss_grad_hessian(
        &mut self,
        x: &[f64],
        g: &mut [f64],
        h: &mut Mat,
    ) -> f64 {
        let l = self.loss_grad(x, g);
        h.as_mut_slice().copy_from_slice(self.q.as_slice());
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::numerics::{check_grad, check_hessian};

    fn fixture() -> QuadraticOracle {
        let q = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        QuadraticOracle::new(q, vec![1.0, 2.0])
    }

    #[test]
    fn gradient_zero_at_solution() {
        let mut o = fixture();
        let x = o.solution().unwrap();
        let mut g = vec![0.0; 2];
        o.grad(&x, &mut g);
        assert!(vector::norm2(&g) < 1e-12);
    }

    #[test]
    fn fd_checks() {
        let mut o = fixture();
        assert!(check_grad(&mut o, &[0.3, -0.7]) < 1e-7);
        assert!(check_hessian(&mut o, &[0.3, -0.7]) < 1e-5);
    }

    #[test]
    fn loss_value_known() {
        let mut o = fixture();
        // f(0) = 0; f(e1) = 2 − 1 = 1.
        assert_eq!(o.loss(&[0.0, 0.0]), 0.0);
        assert_eq!(o.loss(&[1.0, 0.0]), 1.0);
    }
}

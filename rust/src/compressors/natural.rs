//! Natural compression (Horváth et al. 2022) — unbiased stochastic
//! rounding of the mantissa to a power of two, ω = 1/8.
//!
//! The paper added it out of scientific curiosity and found it "behaves
//! remarkably well for FedNL" (§9, App. E.2), noting it "operates at the
//! granularity of bits". We implement it with FP64 bit tricks: for
//! v = ±2ᵉ·m, m ∈ [1, 2), round to ±2ᵉ with probability 2−m and ±2ᵉ⁺¹
//! with probability m−1 (E = 2ᵉ(2−m) + 2ᵉ⁺¹(m−1) = 2ᵉ·m = v).
//!
//! A compressed value is a sign bit + 11-bit exponent; the wire packs it
//! in 16 bits (see [`pack16`]/[`unpack16`]) — a 4× payload shrink over
//! raw f64. FedNL consumes the scaled contractive form: values divided
//! by (1+ω) = 9/8, δ = 8/9.

use super::{Compressed, Compressor, CompressorKind, IndexPayload};
use crate::linalg::packed::PackedUpper;
use crate::rng::{Pcg64, Rng};

/// Unbiased power-of-two stochastic rounding, in scaled contractive form.
#[derive(Debug, Clone)]
pub struct Natural {
    rng: Pcg64,
}

pub const OMEGA: f64 = 1.0 / 8.0;

impl Natural {
    pub fn new() -> Self {
        Self { rng: Pcg64::seed_from_u64(0x4E41_5455_5241_4C21) }
    }

    pub fn with_seed(seed: u64) -> Self {
        Self { rng: Pcg64::seed_from_u64(seed) }
    }

    /// One unbiased natural-rounding draw (bit-trick fast path).
    #[inline]
    pub fn round_natural<R: Rng>(rng: &mut R, v: f64) -> f64 {
        if v == 0.0 || !v.is_finite() {
            return v;
        }
        let bits = v.to_bits();
        // Exact powers of two are fixed points: the mantissa-fraction
        // scan (paper's "granularity of bits") skips the Bernoulli draw
        // entirely — p would be 0, so no randomness is consumed.
        if bits & 0x000F_FFFF_FFFF_FFFF == 0 {
            return v;
        }
        let exp_bits = (bits >> 52) & 0x7FF;
        if exp_bits == 0 {
            // Subnormal: magnitude < 2^-1022 — flush via generic path.
            let mag = v.abs();
            let e = mag.log2().floor();
            let lo = e.exp2();
            let m = mag / lo;
            let up = rng.bernoulli(m - 1.0);
            let out = if up { lo * 2.0 } else { lo };
            return out.copysign(v);
        }
        // m − 1 ∈ [0,1) is exactly the mantissa fraction.
        let frac = f64::from_bits((bits & 0x000F_FFFF_FFFF_FFFF) | (1023u64 << 52)) - 1.0;
        let up = rng.bernoulli(frac);
        let new_exp = if up { exp_bits + 1 } else { exp_bits };
        let sign = bits & 0x8000_0000_0000_0000;
        f64::from_bits(sign | (new_exp.min(0x7FE) << 52))
    }
}

impl Default for Natural {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for Natural {
    fn name(&self) -> String {
        "Natural".into()
    }

    fn kind(&self, _n: usize) -> CompressorKind {
        CompressorKind::Unbiased { omega: OMEGA }
    }

    fn compress(
        &mut self,
        _pu: &PackedUpper,
        src: &[f64],
        _round: u64,
    ) -> Compressed {
        // Values stay pure ± powers of two (16-bit encodable, paper's
        // "granularity of bits"); the contractive 1/(1+ω) factor rides
        // in `scale` and is applied by the consumer.
        let values = src
            .iter()
            .map(|&v| Self::round_natural(&mut self.rng, v))
            .collect();
        Compressed {
            payload: IndexPayload::Dense,
            values,
            scale: 1.0 / (1.0 + OMEGA),
            encoding: super::ValueEncoding::Pow2x16,
            n: src.len() as u32,
        }
    }
}

/// Pack a natural-compressed value (± power of two, pre-scaling) into
/// 16 bits: bit 15 = sign, bits 0..11 = biased exponent, 0 = zero.
pub fn pack16(v: f64) -> u16 {
    if v == 0.0 {
        return 0;
    }
    let bits = v.to_bits();
    let sign = ((bits >> 63) as u16) << 15;
    let exp = ((bits >> 52) & 0x7FF) as u16;
    sign | exp
}

/// Inverse of [`pack16`].
pub fn unpack16(p: u16) -> f64 {
    if p & 0x7FFF == 0 {
        return 0.0;
    }
    let sign = ((p >> 15) as u64) << 63;
    let exp = ((p & 0x7FF) as u64) << 52;
    f64::from_bits(sign | exp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::weighted_norm_sq;

    #[test]
    fn rounds_to_powers_of_two() {
        let mut rng = Pcg64::seed_from_u64(1);
        for &v in &[3.7, -0.3, 1.0, -1024.5, 1e-10, 2.0f64.powi(100)] {
            let r = Natural::round_natural(&mut rng, v);
            let mag = r.abs();
            assert_eq!(mag.log2().fract(), 0.0, "{v} -> {r}");
            assert_eq!(r.signum(), v.signum());
            // Bracketing: |v|/2 < |r| ≤ 2|v| roughly.
            assert!(mag >= v.abs() / 2.0 - 1e-300 && mag <= v.abs() * 2.0);
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Pcg64::seed_from_u64(2);
        for &v in &[3.3, -7.9, 0.011, 1.5] {
            let trials = 60_000;
            let mean: f64 = (0..trials)
                .map(|_| Natural::round_natural(&mut rng, v))
                .sum::<f64>()
                / trials as f64;
            assert!((mean - v).abs() < 0.02 * v.abs(), "{v}: mean {mean}");
        }
    }

    #[test]
    fn exact_powers_are_fixed_points() {
        let mut rng = Pcg64::seed_from_u64(3);
        for e in [-5, 0, 1, 10] {
            let v = 2.0f64.powi(e);
            for _ in 0..100 {
                assert_eq!(Natural::round_natural(&mut rng, v), v);
            }
        }
    }

    #[test]
    fn zero_and_nonfinite_passthrough() {
        let mut rng = Pcg64::seed_from_u64(4);
        assert_eq!(Natural::round_natural(&mut rng, 0.0), 0.0);
        assert!(Natural::round_natural(&mut rng, f64::INFINITY).is_infinite());
    }

    #[test]
    fn variance_bound_omega() {
        // E‖C(x)−x‖² ≤ ω‖x‖² with ω = 1/8 (unscaled form).
        let pu = PackedUpper::new(6);
        let mut rng = Pcg64::seed_from_u64(5);
        let src: Vec<f64> =
            (0..pu.len()).map(|_| rng.next_gaussian()).collect();
        let total = weighted_norm_sq(&pu, &src);
        let mut acc = 0.0;
        let trials = 3000;
        let mut r2 = Pcg64::seed_from_u64(6);
        for _ in 0..trials {
            let mut diff = vec![0.0; src.len()];
            for (i, &v) in src.iter().enumerate() {
                diff[i] = Natural::round_natural(&mut r2, v) - v;
            }
            acc += pu.frobenius_sq_packed(&diff);
        }
        let mean = acc / trials as f64;
        assert!(mean <= OMEGA * total * 1.05, "{mean} > ω·{total}");
    }

    #[test]
    fn pack16_roundtrip() {
        let mut rng = Pcg64::seed_from_u64(7);
        for &v in &[1.0, -2.0, 0.5, -1024.0, 2.0f64.powi(-300), 0.0] {
            let r = Natural::round_natural(&mut rng, v);
            assert_eq!(unpack16(pack16(r)), r, "v={v} r={r}");
        }
    }

    #[test]
    fn compressor_carries_contractive_scale() {
        let pu = PackedUpper::new(4);
        let src = vec![2.0; pu.len()];
        let mut c = Natural::with_seed(8);
        let out = c.compress(&pu, &src, 0);
        assert_eq!(out.values.len(), src.len());
        assert!((out.scale - 8.0 / 9.0).abs() < 1e-16);
        for v in &out.values {
            // 2.0 is a power of two → fixed point; raw value unscaled.
            assert_eq!(*v, 2.0);
        }
        // to_dense applies the scale.
        assert!((out.to_dense()[0] - 2.0 * 8.0 / 9.0).abs() < 1e-15);
        // 16-bit wire accounting (+ fixed codec fields).
        assert_eq!(
            out.wire_bytes(),
            src.len() as u64 * 2 + crate::compressors::CODEC_OVERHEAD_BYTES
        );
    }
}

//! Single-node multi-core simulation (paper §5.12, v39):
//! a persistent worker pool sized to the available cores, clients
//! *statically dispatched* to workers (no work stealing → no
//! congestion), one message channel per direction, master processes
//! replies as they arrive.
//!
//! Determinism: workers compute in parallel but the master re-orders
//! replies before aggregation — round/warm-start messages by client id
//! (f64 reduction order, and hence the FedNL trajectory, identical to
//! [`super::SeqPool`]), loss/gradient partial sums by worker id (fixed
//! reduction order → bit-identical run-to-run; the bucketed association
//! differs from SeqPool's flat sum by normal f64 reassociation).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::ClientPool;
use crate::algorithms::{ClientMsg, ClientState};

enum Cmd {
    Round { x: Arc<Vec<f64>>, round: u64, need_loss: bool },
    EvalLoss { x: Arc<Vec<f64>> },
    LossGrad { x: Arc<Vec<f64>> },
    WarmStart { x: Arc<Vec<f64>> },
    SetAlpha(f64),
    Shutdown,
}

enum Reply {
    Msgs(Vec<ClientMsg>),
    /// (worker id, sum of local losses over the worker's clients,
    /// client count). The worker id lets the master reduce in a fixed
    /// order even though replies arrive in completion order.
    Loss(usize, f64, usize),
    /// (worker id, sum of local losses, sum of local gradients,
    /// client count).
    LossGrad(usize, f64, Vec<f64>, usize),
    /// (client_id, packed H⁰) pairs.
    Warm(Vec<(usize, Vec<f64>)>),
    Ack,
}

struct Worker {
    cmd_tx: Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

/// Thread-pool client simulator.
pub struct ThreadedPool {
    workers: Vec<Worker>,
    reply_rx: Receiver<Reply>,
    n_clients: usize,
    dim: usize,
    default_alpha: f64,
}

impl ThreadedPool {
    /// Distribute `clients` over `n_workers` threads (0 → #cores,
    /// clamped to the client count).
    pub fn new(clients: Vec<ClientState>, n_workers: usize) -> Self {
        assert!(!clients.is_empty());
        let n_clients = clients.len();
        let dim = clients[0].dim();
        let default_alpha = clients[0].alpha;
        let n_workers = if n_workers == 0 {
            crate::utils::available_cores()
        } else {
            n_workers
        }
        .min(n_clients)
        .max(1);

        // Static round-robin dispatch (paper: "clients were statically
        // dispatched to this pool").
        let mut buckets: Vec<Vec<ClientState>> =
            (0..n_workers).map(|_| Vec::new()).collect();
        for (i, c) in clients.into_iter().enumerate() {
            buckets[i % n_workers].push(c);
        }

        let (reply_tx, reply_rx) = channel::<Reply>();
        let workers = buckets
            .into_iter()
            .enumerate()
            .map(|(wid, mut bucket)| {
                let (cmd_tx, cmd_rx) = channel::<Cmd>();
                let tx = reply_tx.clone();
                let handle = std::thread::spawn(move || {
                    while let Ok(cmd) = cmd_rx.recv() {
                        match cmd {
                            Cmd::Round { x, round, need_loss } => {
                                let msgs: Vec<ClientMsg> = bucket
                                    .iter_mut()
                                    .map(|c| c.round(&x, round, need_loss))
                                    .collect();
                                let _ = tx.send(Reply::Msgs(msgs));
                            }
                            Cmd::EvalLoss { x } => {
                                let s: f64 = bucket
                                    .iter_mut()
                                    .map(|c| c.eval_loss(&x))
                                    .sum();
                                let _ = tx
                                    .send(Reply::Loss(wid, s, bucket.len()));
                            }
                            Cmd::LossGrad { x } => {
                                let mut g = vec![0.0; x.len()];
                                let mut s = 0.0;
                                for c in bucket.iter_mut() {
                                    let (l, gi) = c.eval_loss_grad(&x);
                                    s += l;
                                    crate::linalg::vector::axpy(
                                        1.0, &gi, &mut g,
                                    );
                                }
                                let _ = tx.send(Reply::LossGrad(
                                    wid,
                                    s,
                                    g,
                                    bucket.len(),
                                ));
                            }
                            Cmd::WarmStart { x } => {
                                let w = bucket
                                    .iter_mut()
                                    .map(|c| (c.id, c.warm_start(&x)))
                                    .collect();
                                let _ = tx.send(Reply::Warm(w));
                            }
                            Cmd::SetAlpha(a) => {
                                for c in bucket.iter_mut() {
                                    c.alpha = a;
                                }
                                let _ = tx.send(Reply::Ack);
                            }
                            Cmd::Shutdown => break,
                        }
                    }
                });
                Worker { cmd_tx, handle: Some(handle) }
            })
            .collect();

        Self { workers, reply_rx, n_clients, dim, default_alpha }
    }

    fn broadcast(&self, make: impl Fn() -> Cmd) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(make());
        }
    }
}

impl ClientPool for ThreadedPool {
    fn n_clients(&self) -> usize {
        self.n_clients
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn kind_name(&self) -> &'static str {
        "threaded"
    }

    fn default_alpha(&self) -> f64 {
        self.default_alpha
    }

    fn set_alpha(&mut self, alpha: f64) {
        self.broadcast(|| Cmd::SetAlpha(alpha));
        for _ in 0..self.workers.len() {
            let _ = self.reply_rx.recv();
        }
    }

    fn round(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> Vec<ClientMsg> {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::Round { x: Arc::clone(&x), round, need_loss });
        // Process replies as they arrive (paper: "processed messages
        // from clients as they became available"), then restore client
        // order for a deterministic reduction.
        let mut msgs = Vec::with_capacity(self.n_clients);
        for _ in 0..self.workers.len() {
            match self.reply_rx.recv() {
                Ok(Reply::Msgs(m)) => msgs.extend(m),
                _ => panic!("worker died"),
            }
        }
        msgs.sort_by_key(|m| m.client_id);
        msgs
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::EvalLoss { x: Arc::clone(&x) });
        // Collect in arrival order, reduce in worker order: the f64
        // summation order is fixed, so repeated runs are bit-identical.
        let mut parts: Vec<(usize, f64, usize)> =
            Vec::with_capacity(self.workers.len());
        for _ in 0..self.workers.len() {
            match self.reply_rx.recv() {
                Ok(Reply::Loss(wid, s, c)) => parts.push((wid, s, c)),
                _ => panic!("worker died"),
            }
        }
        parts.sort_by_key(|&(wid, _, _)| wid);
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for (_, s, c) in parts {
            sum += s;
            cnt += c;
        }
        debug_assert_eq!(cnt, self.n_clients);
        sum / self.n_clients as f64
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::LossGrad { x: Arc::clone(&x) });
        // Same deterministic reduction: sort partial sums by worker id
        // before accumulating.
        let mut parts: Vec<(usize, f64, Vec<f64>, usize)> =
            Vec::with_capacity(self.workers.len());
        for _ in 0..self.workers.len() {
            match self.reply_rx.recv() {
                Ok(Reply::LossGrad(wid, s, gi, c)) => {
                    parts.push((wid, s, gi, c))
                }
                _ => panic!("worker died"),
            }
        }
        parts.sort_by_key(|&(wid, _, _, _)| wid);
        let mut loss = 0.0;
        let mut g = vec![0.0; x.len()];
        let mut cnt = 0usize;
        for (_, s, gi, c) in parts {
            loss += s;
            crate::linalg::vector::axpy(1.0, &gi, &mut g);
            cnt += c;
        }
        debug_assert_eq!(cnt, self.n_clients);
        let inv_n = 1.0 / self.n_clients as f64;
        crate::linalg::vector::scale(inv_n, &mut g);
        (loss * inv_n, g)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let x = Arc::new(x.to_vec());
        self.broadcast(|| Cmd::WarmStart { x: Arc::clone(&x) });
        let mut all: Vec<(usize, Vec<f64>)> = Vec::with_capacity(self.n_clients);
        for _ in 0..self.workers.len() {
            match self.reply_rx.recv() {
                Ok(Reply::Warm(w)) => all.extend(w),
                _ => panic!("worker died"),
            }
        }
        all.sort_by_key(|(id, _)| *id);
        all.into_iter().map(|(_, p)| p).collect()
    }
}

impl Drop for ThreadedPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd_tx.send(Cmd::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::by_name;
    use crate::coordinator::SeqPool;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn make_clients(n: usize, seed: u64) -> (Vec<ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 7,
            n_samples: n * 30,
            density: 0.6,
            noise: 1.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let cs = ds
            .split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name("topk", d, 2, seed + i as u64).unwrap(),
                    None,
                )
            })
            .collect();
        (cs, d)
    }

    #[test]
    fn threaded_matches_sequential_bitwise() {
        let (cs1, d) = make_clients(6, 31);
        let (cs2, _) = make_clients(6, 31);
        let mut seq = SeqPool::new(cs1);
        let mut thr = ThreadedPool::new(cs2, 3);
        let x = vec![0.1; d];
        for round in 0..5 {
            let a = seq.round(&x, round, true);
            let b = thr.round(&x, round, true);
            assert_eq!(a.len(), b.len());
            for (ma, mb) in a.iter().zip(&b) {
                assert_eq!(ma.client_id, mb.client_id);
                assert_eq!(ma.grad, mb.grad);
                assert_eq!(ma.l_i, mb.l_i);
                assert_eq!(ma.update.values, mb.update.values);
                assert_eq!(ma.loss, mb.loss);
            }
        }
        let la = seq.eval_loss(&x);
        let lb = thr.eval_loss(&x);
        assert!((la - lb).abs() < 1e-12);
    }

    #[test]
    fn pool_sizes() {
        let (cs, _) = make_clients(4, 32);
        let thr = ThreadedPool::new(cs, 0); // auto
        assert_eq!(thr.n_clients(), 4);
        assert!(thr.workers.len() >= 1 && thr.workers.len() <= 4);
    }

    #[test]
    fn warm_start_order_preserved() {
        let (cs, d) = make_clients(5, 33);
        let mut thr = ThreadedPool::new(cs, 2);
        let packs = thr.warm_start(&vec![0.0; d]);
        assert_eq!(packs.len(), 5);
        let plen = d * (d + 1) / 2;
        for p in packs {
            assert_eq!(p.len(), plen);
        }
    }
}

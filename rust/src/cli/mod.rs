//! Hand-rolled command-line parsing (paper component `cmdline`:
//! "C++ cross-platform implementation of useful command line parsing
//! mechanisms"). Self-contained by design — no external CLI crates.
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [--key=value]`.

use std::collections::BTreeMap;

/// Parsed arguments: one positional subcommand + key/value options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected integer, got '{v}'")),
        }
    }

    /// Comma-separated list option (`--key a,b,c`); empty when the
    /// option is absent. Empty segments are dropped, so a trailing
    /// comma is harmless.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|v| {
                v.split(',')
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--rounds", "100", "--compressor=topk", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("rounds"), Some("100"));
        assert_eq!(a.get("compressor"), Some("topk"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--n", "5", "--lam", "0.001"]);
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_f64("lam", 0.0).unwrap(), 0.001);
        assert!(a.get_usize("lam", 0).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse(&["split", "in.txt", "out_dir", "--n", "4"]);
        assert_eq!(a.positional, vec!["in.txt", "out_dir"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["run", "--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn list_option() {
        let a = parse(&["client", "--fallback", "a:1,b:2,"]);
        assert_eq!(a.get_list("fallback"), vec!["a:1", "b:2"]);
        assert!(a.get_list("absent").is_empty());
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' but not '--' is consumed as a value.
        let a = parse(&["x", "--shift", "-3.5"]);
        assert_eq!(a.get_f64("shift", 0.0).unwrap(), -3.5);
    }
}

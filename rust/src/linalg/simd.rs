//! Runtime-dispatched SIMD kernels for the FedNL hot path.
//!
//! The paper's ×1000 speedup program (§5) bottoms out in a handful of
//! dense f64 primitives: dot products and AXPYs (margins, gradients,
//! solvers), the symmetric rank-1 Hessian accumulate (§5.10, ×3.07),
//! the fused sigmoid pass (§5.7, ×1.50) and the |value|²-weighted scans
//! the sparsifying compressors run every round (§5.11). This module
//! implements each primitive three times:
//!
//! * an **AVX-512** path (`avx512`) — 8 doubles per op, compiled only
//!   when the building rustc ships the stable AVX-512 intrinsics
//!   (≥ 1.89, probed by `build.rs` via the `fednl_avx512` cfg) and
//!   entered only when the CPU reports `avx512f`;
//! * an **AVX2+FMA** path (`core::arch::x86_64` intrinsics) selected at
//!   runtime via `is_x86_feature_detected!` — no compile-time feature
//!   flags, so one binary runs everywhere and uses the wide units when
//!   they exist;
//! * a **portable scalar** path ([`scalar`]), 4-way unrolled with
//!   independent accumulators so LLVM can autovectorize to whatever the
//!   baseline target offers (SSE2 on x86-64, NEON on aarch64).
//!
//! Dispatch is resolved once per process and cached in an atomic, so a
//! kernel call costs one relaxed load on top of the work itself.
//! `FEDNL_FORCE_ISA={scalar,avx2,avx512}` pins the decision for CI and
//! A/B runs (clamped to what the host and build support, with a
//! one-time warning); `FEDNL_FORCE_SCALAR=1` stays as a back-compat
//! alias for `FEDNL_FORCE_ISA=scalar`.
//!
//! **Determinism contract:** for a fixed ISA decision every kernel
//! reduces in a fixed order (fixed lane count, fixed accumulator tree),
//! so repeated runs on the same machine produce bit-identical results —
//! the property [`crate::coordinator::ThreadedPool`] relies on for
//! bit-reproducible trajectories. The AVX-512 path is constructed to be
//! **bit-identical to AVX2** for every kernel: its 512-bit accumulators
//! are lane-concatenations of AVX2's 256-bit accumulator pairs, its
//! reductions extract those halves and finish with the AVX2 combine
//! tree, and its FMA coverage matches AVX2's element for element (an
//! 8-wide loop, one 4-wide step, then the same scalar tail). Enabling
//! the wider tier therefore never changes a trajectory; only
//! scalar ↔ vector moves reassociate (tests bound this by an n·ε-scaled
//! tolerance). Integer kernels ([`binned_accumulate`]) are exact and
//! bit-identical across **all** tiers.
//!
//! **Sigmoid accuracy budget:** [`sigmoid_neg_scan`] evaluates σ(−z)
//! with a branch-free polynomial exp (the fdlibm reduction, plain
//! mul/add/sub/div only — no FMA — so every tier computes the same
//! rounding sequence). Design target: ≤ 2 ulp against the true σ;
//! tests assert ≤ 3 ulp against the libm reference on [−40, 40] and
//! ≤ 4 ulp over the full range, plus exact saturation (σ(x ≤ −746) = 0,
//! σ(x ≥ 746) = 1, σ(±0) = ½ exactly). The polynomial output is
//! per-element bit-identical across all three tiers. `FEDNL_EXACT_EXP=1`
//! routes the scan through libm ([`sigmoid_exact`]) instead, which
//! reproduces the pre-polynomial bitstream for determinism suites.

use std::sync::atomic::{AtomicU8, Ordering};

const ISA_UNKNOWN: u8 = 0;
const ISA_SCALAR: u8 = 1;
const ISA_AVX2: u8 = 2;
const ISA_AVX512: u8 = 3;

static ISA: AtomicU8 = AtomicU8::new(ISA_UNKNOWN);

/// CI / debugging override: `FEDNL_FORCE_ISA={scalar,avx2,avx512}` pins
/// the dispatcher to one tier so every ISA path gets exercised on every
/// PR regardless of the host. An empty/whitespace value counts as
/// unset; an unknown value panics (a typo must never silently fall back
/// to autodetect). `FEDNL_FORCE_SCALAR=1` (any value other than `0`)
/// remains as an alias for `FEDNL_FORCE_ISA=scalar`.
fn forced_isa() -> Option<u8> {
    if let Some(v) = std::env::var_os("FEDNL_FORCE_ISA") {
        let v = v.to_string_lossy();
        let v = v.trim();
        if !v.is_empty() {
            return Some(match v {
                "scalar" => ISA_SCALAR,
                "avx2" => ISA_AVX2,
                "avx512" => ISA_AVX512,
                other => panic!(
                    "FEDNL_FORCE_ISA={other:?}: expected scalar | avx2 \
                     | avx512"
                ),
            });
        }
    }
    match std::env::var_os("FEDNL_FORCE_SCALAR") {
        Some(v) if !v.is_empty() && v != "0" => Some(ISA_SCALAR),
        _ => None,
    }
}

#[cold]
fn detect() -> u8 {
    let hw = detect_hw();
    let isa = match forced_isa() {
        Some(want) => {
            if want > hw {
                // One-time (detection is cached): forcing a tier the
                // host or build can't run clamps instead of crashing,
                // so `FEDNL_FORCE_ISA=avx512` is safe everywhere.
                eprintln!(
                    "fednl: FEDNL_FORCE_ISA wants {} but this \
                     host/build supports at most {}; clamping",
                    tier_name(want),
                    tier_name(hw)
                );
            }
            want.min(hw)
        }
        None => hw,
    };
    ISA.store(isa, Ordering::Relaxed);
    isa
}

fn tier_name(isa: u8) -> &'static str {
    match isa {
        ISA_AVX512 => "avx512",
        ISA_AVX2 => "avx2",
        _ => "scalar",
    }
}

/// Host CPU can run the AVX2+FMA tier.
#[cfg(target_arch = "x86_64")]
fn hw_avx2() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn hw_avx2() -> bool {
    false
}

/// Host CPU can run the AVX-512 tier *and* this build compiled it (the
/// intrinsics need rustc ≥ 1.89; see `build.rs`).
#[cfg(all(target_arch = "x86_64", fednl_avx512))]
fn hw_avx512() -> bool {
    hw_avx2() && is_x86_feature_detected!("avx512f")
}

#[cfg(not(all(target_arch = "x86_64", fednl_avx512)))]
fn hw_avx512() -> bool {
    false
}

fn detect_hw() -> u8 {
    if hw_avx512() {
        ISA_AVX512
    } else if hw_avx2() {
        ISA_AVX2
    } else {
        ISA_SCALAR
    }
}

#[inline(always)]
fn isa() -> u8 {
    let isa = ISA.load(Ordering::Relaxed);
    if isa == ISA_UNKNOWN {
        return detect();
    }
    isa
}

/// Name of the dispatched instruction set ("avx512", "avx2" or
/// "scalar") — used by benches and `BENCH_kernels.json`.
pub fn isa_name() -> &'static str {
    tier_name(isa())
}

/// An explicitly pinnable kernel tier — tests and benches iterate
/// [`Isa::ALL`], skip tiers where [`isa_available`] is false, and call
/// the `*_on` kernel variants to compare paths on one host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Avx2,
    Avx512,
}

impl Isa {
    pub const ALL: [Isa; 3] = [Isa::Scalar, Isa::Avx2, Isa::Avx512];

    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }
}

/// Whether `which` can execute on this host and build. Scalar is always
/// available; AVX-512 additionally requires a compiler new enough to
/// ship the intrinsics (`fednl_avx512`, see `build.rs`).
pub fn isa_available(which: Isa) -> bool {
    match which {
        Isa::Scalar => true,
        Isa::Avx2 => hw_avx2(),
        Isa::Avx512 => hw_avx512(),
    }
}

// ---------------------------------------------------------------------
// Dispatched entry points.
// ---------------------------------------------------------------------

/// Dot product `Σ a_i·b_i`.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    // Release-mode check: the vector paths do raw loads sized by `a`.
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => return unsafe { avx512::dot(a, b) },
            ISA_AVX2 => return unsafe { avx2::dot(a, b) },
            _ => {}
        }
    }
    scalar::dot(a, b)
}

/// `y += alpha * x` (AXPY).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    // Release-mode check: the vector paths do raw stores sized by `x`.
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => return unsafe { avx512::axpy(alpha, x, y) },
            ISA_AVX2 => return unsafe { avx2::axpy(alpha, x, y) },
            _ => {}
        }
    }
    scalar::axpy(alpha, x, y)
}

/// Squared Euclidean norm `Σ x_i²`.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// `out = a + alpha * b` (fused vector-vector, paper v42).
#[inline]
pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64], out: &mut [f64]) {
    assert!(a.len() == b.len() && b.len() == out.len());
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => {
                return unsafe { avx512::add_scaled(a, alpha, b, out) }
            }
            ISA_AVX2 => return unsafe { avx2::add_scaled(a, alpha, b, out) },
            _ => {}
        }
    }
    scalar::add_scaled(a, alpha, b, out)
}

/// `max_i |x_i|` (ℓ∞ scan; compressor prefilters and `norm_inf`).
#[inline]
pub fn abs_max(x: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => return unsafe { avx512::abs_max(x) },
            ISA_AVX2 => return unsafe { avx2::abs_max(x) },
            _ => {}
        }
    }
    scalar::abs_max(x)
}

/// Elementwise energy scan `out_i = w_i · v_i²` — the Frobenius-weighted
/// magnitude pass TopK/TopLEK selection runs over the packed upper
/// triangle every round (§5.11).
#[inline]
pub fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
    assert!(w.len() == v.len() && v.len() == out.len());
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => return unsafe { avx512::energy_scan(w, v, out) },
            ISA_AVX2 => return unsafe { avx2::energy_scan(w, v, out) },
            _ => {}
        }
    }
    scalar::energy_scan(w, v, out)
}

/// Weighted squared norm `Σ w_i · v_i²` (packed Frobenius accounting).
#[inline]
pub fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
    assert_eq!(w.len(), v.len());
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => return unsafe { avx512::weighted_norm2_sq(w, v) },
            ISA_AVX2 => return unsafe { avx2::weighted_norm2_sq(w, v) },
            _ => {}
        }
    }
    scalar::weighted_norm2_sq(w, v)
}

/// Logistic-Hessian weight scan `out_i = scale · s_i · (1 − s_i)` from
/// cached sigmoids (§5.7: σ(z)σ(−z) derived from one σ evaluation).
#[inline]
pub fn sigmoid_variance_scan(s: &[f64], scale: f64, out: &mut [f64]) {
    assert_eq!(s.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => {
                return unsafe {
                    avx512::sigmoid_variance_scan(s, scale, out)
                }
            }
            ISA_AVX2 => {
                return unsafe { avx2::sigmoid_variance_scan(s, scale, out) }
            }
            _ => {}
        }
    }
    scalar::sigmoid_variance_scan(s, scale, out)
}

/// Symmetric rank-1 accumulate over the upper triangle (§5.10):
/// `data[u·d + v] += Σ_b h_b · a_b[u] · a_b[v]` for `u ≤ v`, processing
/// 4 samples per sweep. `data` is the row-major buffer of a d×d matrix;
/// `samples` are row slices of length d. The single hottest kernel in
/// FedNL — the AVX2 path runs 4 FMAs per 4 columns.
pub fn sym_rank1_upper(
    data: &mut [f64],
    d: usize,
    samples: &[&[f64]],
    h: &[f64],
) {
    // Release-mode checks: the AVX2 path reads d elements per sample
    // and writes rows of `data` through raw pointers.
    assert_eq!(data.len(), d * d);
    sym_rank1_upper_rows(data, d, 0, d, samples, h)
}

/// Row-ranged rank-1 accumulate: `block` holds rows `u0..u1` of a d×d
/// row-major matrix and receives `block[(u−u0)·d + v] += Σ_b h_b ·
/// a_b[u] · a_b[v]` for `u0 ≤ u < u1`, `u ≤ v`. The building block of
/// [`sym_rank1_upper_threaded`]; per-entry accumulation order is
/// identical to [`sym_rank1_upper`].
pub fn sym_rank1_upper_rows(
    block: &mut [f64],
    d: usize,
    u0: usize,
    u1: usize,
    samples: &[&[f64]],
    h: &[f64],
) {
    assert!(u0 <= u1 && u1 <= d);
    assert_eq!(block.len(), (u1 - u0) * d);
    assert_eq!(samples.len(), h.len());
    assert!(samples.iter().all(|s| s.len() == d));
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => {
                return unsafe {
                    avx512::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
                }
            }
            ISA_AVX2 => {
                return unsafe {
                    avx2::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
                }
            }
            _ => {}
        }
    }
    scalar::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
}

/// Multi-threaded rank-1 accumulate (the ROADMAP's "thread the §5.10
/// accumulate across samples *within* one client"): the packed upper
/// triangle is partitioned into contiguous **row blocks** of roughly
/// equal triangle area, one scoped thread per block, each sweeping all
/// samples over its own rows. Every matrix entry is written by exactly
/// one thread with the same per-sample accumulation order as the
/// single-threaded kernel, so the result is **bit-identical for any
/// thread count** — trajectories do not change when intra-client
/// threading is enabled.
pub fn sym_rank1_upper_threaded(
    data: &mut [f64],
    d: usize,
    samples: &[&[f64]],
    h: &[f64],
    n_threads: usize,
) {
    assert_eq!(data.len(), d * d);
    assert_eq!(samples.len(), h.len());
    assert!(samples.iter().all(|s| s.len() == d));
    let t = n_threads.max(1).min(d.max(1));
    // Tiny problems: the spawn overhead dwarfs the work.
    if t == 1 || d < 32 {
        return sym_rank1_upper_rows(data, d, 0, d, samples, h);
    }
    let bounds = triangle_row_blocks(d, t);
    std::thread::scope(|scope| {
        let mut rest: &mut [f64] = data;
        for w in bounds.windows(2) {
            let (u0, u1) = (w[0], w[1]);
            if u0 == u1 {
                continue;
            }
            let r = std::mem::take(&mut rest);
            let (block, tail) = r.split_at_mut((u1 - u0) * d);
            rest = tail;
            scope.spawn(move || {
                sym_rank1_upper_rows(block, d, u0, u1, samples, h)
            });
        }
    });
}

/// Partition rows `0..d` into `t` contiguous blocks with approximately
/// equal upper-triangle area (row u owns d−u entries). Returns t+1
/// boundaries starting at 0 and ending at d; deterministic in (d, t).
fn triangle_row_blocks(d: usize, t: usize) -> Vec<usize> {
    let total = d * (d + 1) / 2;
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0);
    let mut acc = 0usize;
    let mut next = 1usize;
    for u in 0..d {
        acc += d - u;
        if next < t && acc * t >= total * next {
            bounds.push(u + 1);
            next += 1;
        }
    }
    while bounds.len() < t + 1 {
        bounds.push(d);
    }
    bounds
}

/// Intra-client threads for the rank-1 Hessian accumulate (1 = off,
/// the default — client-level parallelism via `ThreadedPool` already
/// saturates multi-core hosts; raise it for few-client / sequential
/// runs, e.g. `fednl train --intra-threads N`).
static INTRA_THREADS: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(1);

pub fn set_intra_threads(n: usize) {
    INTRA_THREADS.store(n.max(1), Ordering::Relaxed);
}

pub fn intra_threads() -> usize {
    INTRA_THREADS.load(Ordering::Relaxed)
}

/// Bulk superaccumulate (reproducible-summation layer, see
/// [`crate::linalg::reduce`]): fold every element of `xs` into the
/// fixed-point accumulator `limbs`, returning the accumulated
/// special-value mask (`reduce::SP_*` bits) for the non-finite terms.
///
/// Unlike the float kernels above, the arithmetic here is **integer
/// exact**, so the AVX2 and scalar paths produce bit-identical limbs —
/// dispatch affects throughput only, never the sum. The kernel
/// carry-propagates internally and leaves `limbs` in canonical form.
#[inline]
pub fn binned_accumulate(
    limbs: &mut [i64; super::reduce::LIMBS],
    xs: &[f64],
) -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => {
                return unsafe { avx512::binned_accumulate(limbs, xs) }
            }
            ISA_AVX2 => return unsafe { avx2::binned_accumulate(limbs, xs) },
            _ => {}
        }
    }
    scalar::binned_accumulate(limbs, xs)
}

/// Chunk length between carry propagations inside the bulk kernels
/// (each term adds < 2^32 to a limb; 2^28 chunks keep limbs far from
/// i64 overflow even on top of canonical state).
const BINNED_CHUNK: usize = 1 << 28;

/// Wrap-around contiguous gather: `out = src[(start + t) mod n]` for
/// `t = 0..k` — at most two `memcpy`s (RandSeqK's cache-aware selection,
/// paper App. C.4).
#[inline]
pub fn gather_window(
    src: &[f64],
    start: usize,
    k: usize,
    out: &mut Vec<f64>,
) {
    let n = src.len();
    debug_assert!(start < n && k <= n);
    out.clear();
    let first = (n - start).min(k);
    out.extend_from_slice(&src[start..start + first]);
    out.extend_from_slice(&src[..k - first]);
}

// ---------------------------------------------------------------------
// Vectorized sigmoid (polynomial exp with a tested accuracy budget).
// ---------------------------------------------------------------------

/// Exact-path sigmoid σ(x) = 1/(1+e⁻ˣ) via libm `exp` — the historical
/// bitstream. [`crate::oracle::sigmoid`] forwards here; the fused scan
/// falls back to it under `FEDNL_EXACT_EXP=1`.
#[inline]
pub fn sigmoid_exact(x: f64) -> f64 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

// fdlibm e_exp.c reduction constants: x = k·ln2 + r, |r| ≤ ln2/2, with
// ln2 split hi/lo so `k·LN2_HI` is exact for the k range used here.
// Defined by bit pattern — the hi/lo-split exactness argument depends
// on these exact doubles, not on a decimal approximation of them.
/// 1.44269504088896338700e0 (1/ln2).
const EXP_INV_LN2: f64 = f64::from_bits(0x3FF71547652B82FE);
/// 6.93147180369123816490e-1 (ln2 high part, 20 trailing zero bits).
const EXP_LN2_HI: f64 = f64::from_bits(0x3FE62E42FEE00000);
/// 1.90821492927058770002e-10 (ln2 low part).
const EXP_LN2_LO: f64 = f64::from_bits(0x3DEA39EF35793C76);
// Minimax coefficients for the fdlibm core polynomial on |r| ≤ ln2/2.
/// 1.66666666666666019037e-1.
const EXP_P1: f64 = f64::from_bits(0x3FC555555555553E);
/// -2.77777777770155933842e-3.
const EXP_P2: f64 = f64::from_bits(0xBF66C16C16BEBD93);
/// 6.61375632143793436117e-5.
const EXP_P3: f64 = f64::from_bits(0x3F11566AAF25DE2C);
/// -1.65339022054652515390e-6.
const EXP_P4: f64 = f64::from_bits(0xBEBBBD41C5D26BF1);
/// 4.13813679705723846039e-8.
const EXP_P5: f64 = f64::from_bits(0x3E66376972BEA4D0);
// exp(−746) underflows to zero even through the subnormal range;
// clamping the reduced argument here keeps `k` in a range where the
// two-step scaling below cannot overflow an exponent field.
const SIG_ARG_MIN: f64 = -746.0;

/// 2^k for k ∈ [−1022, 1023] by direct exponent-field construction.
#[inline]
fn pow2i(k: i32) -> f64 {
    f64::from_bits((((k + 1023) as i64) as u64) << 52)
}

/// Polynomial-path sigmoid, the scalar reference every vector lane
/// mirrors operation for operation (plain mul/add/sub/div, no FMA):
/// computes e = exp(−|x|) via the fdlibm reduction, then σ(x) as
/// 1/(1+e) or e/(1+e) by sign. Public so tests can assert the ulp
/// budget and cross-tier bit-identity directly.
#[inline]
pub fn sigmoid_poly(x: f64) -> f64 {
    let ax = -x.abs();
    // NaN passes the comparison path unclamped and poisons the result.
    let a = if ax < SIG_ARG_MIN { SIG_ARG_MIN } else { ax };
    let k = (a * EXP_INV_LN2).round_ties_even() as i32;
    let kd = k as f64;
    let hi = a - kd * EXP_LN2_HI;
    let lo = kd * EXP_LN2_LO;
    let r = hi - lo;
    let t = r * r;
    let c = r - t
        * (EXP_P1 + t * (EXP_P2 + t * (EXP_P3 + t * (EXP_P4 + t * EXP_P5))));
    let y = 1.0 - ((lo - (r * c) / (2.0 - c)) - hi);
    // Two-step 2^k scaling: k ∈ [−1076, 0], each half ∈ [−538, 0] is a
    // normal power of two, and the first multiply is exact.
    let k1 = k >> 1;
    let k2 = k - k1;
    let e = (y * pow2i(k1)) * pow2i(k2);
    let num = if x >= 0.0 { 1.0 } else { e };
    num / (1.0 + e)
}

const EXACT_UNKNOWN: u8 = 0;
const EXACT_LIBM: u8 = 1;
const EXACT_POLY: u8 = 2;

static EXACT_EXP: AtomicU8 = AtomicU8::new(EXACT_UNKNOWN);

/// Latched `FEDNL_EXACT_EXP` check: non-empty, non-`0` routes the fused
/// sigmoid scan through libm `exp` (the pre-polynomial bitstream).
fn exact_exp() -> bool {
    match EXACT_EXP.load(Ordering::Relaxed) {
        EXACT_LIBM => true,
        EXACT_POLY => false,
        _ => {
            let exact = match std::env::var_os("FEDNL_EXACT_EXP") {
                Some(v) => !v.is_empty() && v != "0",
                None => false,
            };
            EXACT_EXP.store(
                if exact { EXACT_LIBM } else { EXACT_POLY },
                Ordering::Relaxed,
            );
            exact
        }
    }
}

/// Fused sigmoid scan `out[i] = σ(−z[i])` — the oracle's per-sample
/// pass (§5.7) with the margin sign folded in. Polynomial path by
/// default (accuracy budget in the module docs, asserted by
/// `tests/simd_kernels.rs`); `FEDNL_EXACT_EXP=1` switches to libm.
#[inline]
pub fn sigmoid_neg_scan(z: &[f64], out: &mut [f64]) {
    assert_eq!(z.len(), out.len());
    if exact_exp() {
        for (o, &zi) in out.iter_mut().zip(z.iter()) {
            *o = sigmoid_exact(-zi);
        }
        return;
    }
    #[cfg(target_arch = "x86_64")]
    {
        match isa() {
            #[cfg(fednl_avx512)]
            ISA_AVX512 => return unsafe { avx512::sigmoid_neg_scan(z, out) },
            ISA_AVX2 => return unsafe { avx2::sigmoid_neg_scan(z, out) },
            _ => {}
        }
    }
    scalar::sigmoid_neg_scan(z, out)
}

// ---------------------------------------------------------------------
// Pinned-tier kernel variants (tests / benches).
// ---------------------------------------------------------------------
//
// Each `*_on` runs the kernel on an explicit [`Isa`] tier instead of
// the dispatched one. Callers must check [`isa_available`] first; the
// wrappers assert it (running AVX code on a host without it is UB, not
// a wrong answer).

macro_rules! assert_isa {
    ($which:expr) => {
        assert!(
            isa_available($which),
            "{} not available on this host/build",
            $which.name()
        );
    };
}

/// [`dot`] pinned to `which`.
pub fn dot_on(which: Isa, a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::dot(a, b),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::dot(a, b) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`axpy`] pinned to `which`.
pub fn axpy_on(which: Isa, alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::axpy(alpha, x, y),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::axpy(alpha, x, y) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`add_scaled`] pinned to `which`.
pub fn add_scaled_on(
    which: Isa,
    a: &[f64],
    alpha: f64,
    b: &[f64],
    out: &mut [f64],
) {
    assert!(a.len() == b.len() && b.len() == out.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::add_scaled(a, alpha, b, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::add_scaled(a, alpha, b, out) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::add_scaled(a, alpha, b, out) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`abs_max`] pinned to `which`.
pub fn abs_max_on(which: Isa, x: &[f64]) -> f64 {
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::abs_max(x),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::abs_max(x) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::abs_max(x) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`energy_scan`] pinned to `which`.
pub fn energy_scan_on(which: Isa, w: &[f64], v: &[f64], out: &mut [f64]) {
    assert!(w.len() == v.len() && v.len() == out.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::energy_scan(w, v, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::energy_scan(w, v, out) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::energy_scan(w, v, out) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`weighted_norm2_sq`] pinned to `which`.
pub fn weighted_norm2_sq_on(which: Isa, w: &[f64], v: &[f64]) -> f64 {
    assert_eq!(w.len(), v.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::weighted_norm2_sq(w, v),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::weighted_norm2_sq(w, v) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::weighted_norm2_sq(w, v) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`sigmoid_variance_scan`] pinned to `which`.
pub fn sigmoid_variance_scan_on(
    which: Isa,
    s: &[f64],
    scale: f64,
    out: &mut [f64],
) {
    assert_eq!(s.len(), out.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::sigmoid_variance_scan(s, scale, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::sigmoid_variance_scan(s, scale, out) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe {
            avx512::sigmoid_variance_scan(s, scale, out)
        },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`sym_rank1_upper`] pinned to `which` (full-matrix rows `0..d`).
pub fn sym_rank1_upper_on(
    which: Isa,
    data: &mut [f64],
    d: usize,
    samples: &[&[f64]],
    h: &[f64],
) {
    assert_eq!(data.len(), d * d);
    sym_rank1_upper_rows_on(which, data, d, 0, d, samples, h)
}

/// [`sym_rank1_upper_rows`] pinned to `which`.
pub fn sym_rank1_upper_rows_on(
    which: Isa,
    block: &mut [f64],
    d: usize,
    u0: usize,
    u1: usize,
    samples: &[&[f64]],
    h: &[f64],
) {
    assert!(u0 <= u1 && u1 <= d);
    assert_eq!(block.len(), (u1 - u0) * d);
    assert_eq!(samples.len(), h.len());
    assert!(samples.iter().all(|s| s.len() == d));
    assert_isa!(which);
    match which {
        Isa::Scalar => {
            scalar::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
        }
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            avx2::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
        },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe {
            avx512::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
        },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// [`binned_accumulate`] pinned to `which` (limb-identical across all
/// tiers — the property `tests/reduce_props.rs` asserts).
pub fn binned_accumulate_on(
    which: Isa,
    limbs: &mut [i64; super::reduce::LIMBS],
    xs: &[f64],
) -> u8 {
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::binned_accumulate(limbs, xs),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::binned_accumulate(limbs, xs) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::binned_accumulate(limbs, xs) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

/// Polynomial-path [`sigmoid_neg_scan`] pinned to `which` (ignores the
/// `FEDNL_EXACT_EXP` latch — tests compare tiers directly).
pub fn sigmoid_neg_scan_on(which: Isa, z: &[f64], out: &mut [f64]) {
    assert_eq!(z.len(), out.len());
    assert_isa!(which);
    match which {
        Isa::Scalar => scalar::sigmoid_neg_scan(z, out),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::sigmoid_neg_scan(z, out) },
        #[cfg(all(target_arch = "x86_64", fednl_avx512))]
        Isa::Avx512 => unsafe { avx512::sigmoid_neg_scan(z, out) },
        #[allow(unreachable_patterns)]
        _ => unreachable!(),
    }
}

// ---------------------------------------------------------------------
// Portable scalar fallbacks (4-way unrolled, autovectorizer-friendly).
// ---------------------------------------------------------------------

/// Reference implementations: manually unrolled scalar loops with
/// independent accumulators (paper v32). Public so benches can A/B the
/// dispatched path against them and tests can bound the divergence.
pub mod scalar {
    /// Dot product with 4 independent accumulators.
    #[inline]
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in chunks * 4..n {
            s += a[i] * b[i];
        }
        s
    }

    /// `y += alpha * x`.
    #[inline]
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x.iter()) {
            *yi += alpha * *xi;
        }
    }

    /// `out = a + alpha * b`.
    #[inline]
    pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64], out: &mut [f64]) {
        for i in 0..a.len() {
            out[i] = a[i] + alpha * b[i];
        }
    }

    /// `max |x_i|`.
    #[inline]
    pub fn abs_max(x: &[f64]) -> f64 {
        x.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// `out_i = w_i · v_i²`.
    #[inline]
    pub fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
        for i in 0..v.len() {
            out[i] = w[i] * (v[i] * v[i]);
        }
    }

    /// `Σ w_i · v_i²` with 4 independent accumulators.
    #[inline]
    pub fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
        let n = v.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += w[i] * (v[i] * v[i]);
            s1 += w[i + 1] * (v[i + 1] * v[i + 1]);
            s2 += w[i + 2] * (v[i + 2] * v[i + 2]);
            s3 += w[i + 3] * (v[i + 3] * v[i + 3]);
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in chunks * 4..n {
            s += w[i] * (v[i] * v[i]);
        }
        s
    }

    /// `out_i = scale · s_i · (1 − s_i)`.
    #[inline]
    pub fn sigmoid_variance_scan(s: &[f64], scale: f64, out: &mut [f64]) {
        for i in 0..s.len() {
            out[i] = scale * (s[i] * (1.0 - s[i]));
        }
    }

    /// `out_i = σ(−z_i)`, polynomial path (see [`super::sigmoid_poly`]
    /// — the per-element reference the vector tiers reproduce bit for
    /// bit).
    #[inline]
    pub fn sigmoid_neg_scan(z: &[f64], out: &mut [f64]) {
        for i in 0..z.len() {
            out[i] = super::sigmoid_poly(-z[i]);
        }
    }

    /// Bulk superaccumulate, 4-way unrolled (exact integer scatter;
    /// see the dispatched [`super::binned_accumulate`]). The unroll
    /// overlaps the four independent decomposes — the limb adds are
    /// order-free because integer addition is associative.
    pub fn binned_accumulate(
        limbs: &mut [i64; crate::linalg::reduce::LIMBS],
        xs: &[f64],
    ) -> u8 {
        use crate::linalg::reduce::{accumulate_one, propagate_limbs};
        let mut special = 0u8;
        for chunk in xs.chunks(super::BINNED_CHUNK) {
            let mut i = 0;
            while i + 4 <= chunk.len() {
                special |= accumulate_one(limbs, chunk[i]);
                special |= accumulate_one(limbs, chunk[i + 1]);
                special |= accumulate_one(limbs, chunk[i + 2]);
                special |= accumulate_one(limbs, chunk[i + 3]);
                i += 4;
            }
            while i < chunk.len() {
                special |= accumulate_one(limbs, chunk[i]);
                i += 1;
            }
            propagate_limbs(limbs);
        }
        if xs.is_empty() {
            propagate_limbs(limbs);
        }
        special
    }

    /// Upper-triangle rank-1 accumulate, 4 samples per sweep with four
    /// independent scalar chains (paper v26+v52).
    pub fn sym_rank1_upper(
        data: &mut [f64],
        d: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        sym_rank1_upper_rows(data, d, 0, d, samples, h)
    }

    /// Row-ranged variant of [`sym_rank1_upper`]: accumulates rows
    /// `u0..u1` only, with `block` holding exactly those rows
    /// (`block.len() == (u1 − u0) · d`). The per-entry accumulation
    /// order is identical to the full kernel — the row partition of the
    /// threaded accumulate stays bit-identical to single-threaded.
    pub fn sym_rank1_upper_rows(
        block: &mut [f64],
        d: usize,
        u0: usize,
        u1: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        debug_assert_eq!(block.len(), (u1 - u0) * d);
        let mut b = 0;
        while b + 4 <= samples.len() {
            let (a0, a1, a2, a3) =
                (samples[b], samples[b + 1], samples[b + 2], samples[b + 3]);
            let (h0, h1, h2, h3) = (h[b], h[b + 1], h[b + 2], h[b + 3]);
            for u in u0..u1 {
                let c0 = h0 * a0[u];
                let c1 = h1 * a1[u];
                let c2 = h2 * a2[u];
                let c3 = h3 * a3[u];
                let r = u - u0;
                let row = &mut block[r * d..(r + 1) * d];
                for v in u..d {
                    row[v] +=
                        c0 * a0[v] + c1 * a1[v] + c2 * a2[v] + c3 * a3[v];
                }
            }
            b += 4;
        }
        while b < samples.len() {
            let a = samples[b];
            let hb = h[b];
            for u in u0..u1 {
                let c = hb * a[u];
                let r = u - u0;
                let row = &mut block[r * d..(r + 1) * d];
                for v in u..d {
                    row[v] += c * a[v];
                }
            }
            b += 1;
        }
    }
}

// ---------------------------------------------------------------------
// AVX2 + FMA path (x86-64 only; entered only after runtime detection).
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Horizontal sum of a 256-bit lane in a fixed order:
    /// (l0 + l1) + (l2 + l3).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), v);
        (buf[0] + buf[1]) + (buf[2] + buf[3])
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut acc2 = _mm256_setzero_pd();
        let mut acc3 = _mm256_setzero_pd();
        let mut i = 0;
        // 16 doubles per iteration: 4 independent FMA chains.
        while i + 16 <= n {
            acc0 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i)),
                _mm256_loadu_pd(pb.add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 4)),
                _mm256_loadu_pd(pb.add(i + 4)),
                acc1,
            );
            acc2 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 8)),
                _mm256_loadu_pd(pb.add(i + 8)),
                acc2,
            );
            acc3 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 12)),
                _mm256_loadu_pd(pb.add(i + 12)),
                acc3,
            );
            i += 16;
        }
        while i + 4 <= n {
            acc0 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i)),
                _mm256_loadu_pd(pb.add(i)),
                acc0,
            );
            i += 4;
        }
        // Fixed combination order → deterministic reduction.
        let acc = _mm256_add_pd(
            _mm256_add_pd(acc0, acc1),
            _mm256_add_pd(acc2, acc3),
        );
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let va = _mm256_set1_pd(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let y0 = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(px.add(i)),
                _mm256_loadu_pd(py.add(i)),
            );
            let y1 = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(px.add(i + 4)),
                _mm256_loadu_pd(py.add(i + 4)),
            );
            _mm256_storeu_pd(py.add(i), y0);
            _mm256_storeu_pd(py.add(i + 4), y1);
            i += 8;
        }
        while i + 4 <= n {
            let y0 = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(px.add(i)),
                _mm256_loadu_pd(py.add(i)),
            );
            _mm256_storeu_pd(py.add(i), y0);
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn add_scaled(
        a: &[f64],
        alpha: f64,
        b: &[f64],
        out: &mut [f64],
    ) {
        let n = a.len();
        let va = _mm256_set1_pd(alpha);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let o = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(pb.add(i)),
                _mm256_loadu_pd(pa.add(i)),
            );
            _mm256_storeu_pd(po.add(i), o);
            i += 4;
        }
        while i < n {
            out[i] = a[i] + alpha * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn abs_max(x: &[f64]) -> f64 {
        let n = x.len();
        let px = x.as_ptr();
        // Clear the sign bit instead of computing |x| lane by lane.
        let mask = _mm256_castsi256_pd(_mm256_set1_epi64x(i64::MAX));
        let mut m = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm256_and_pd(mask, _mm256_loadu_pd(px.add(i)));
            // Operand order matters: VMAXPD returns the *second* operand
            // on NaN, so keeping the accumulator there makes NaN inputs
            // transparent — same semantics as scalar `f64::max`.
            m = _mm256_max_pd(v, m);
            i += 4;
        }
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), m);
        let mut s = buf[0].max(buf[1]).max(buf[2]).max(buf[3]);
        while i < n {
            s = s.max(x[i].abs());
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
        let n = v.len();
        let (pw, pv) = (w.as_ptr(), v.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let vv = _mm256_loadu_pd(pv.add(i));
            let e =
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), _mm256_mul_pd(vv, vv));
            _mm256_storeu_pd(po.add(i), e);
            i += 4;
        }
        while i < n {
            out[i] = w[i] * (v[i] * v[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
        let n = v.len();
        let (pw, pv) = (w.as_ptr(), v.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v0 = _mm256_loadu_pd(pv.add(i));
            let v1 = _mm256_loadu_pd(pv.add(i + 4));
            acc0 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), v0),
                v0,
                acc0,
            );
            acc1 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i + 4)), v1),
                v1,
                acc1,
            );
            i += 8;
        }
        while i + 4 <= n {
            let v0 = _mm256_loadu_pd(pv.add(i));
            acc0 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), v0),
                v0,
                acc0,
            );
            i += 4;
        }
        let mut s = hsum(_mm256_add_pd(acc0, acc1));
        while i < n {
            s += w[i] * (v[i] * v[i]);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sigmoid_variance_scan(
        s: &[f64],
        scale: f64,
        out: &mut [f64],
    ) {
        let n = s.len();
        let vscale = _mm256_set1_pd(scale);
        let one = _mm256_set1_pd(1.0);
        let ps = s.as_ptr();
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let sv = _mm256_loadu_pd(ps.add(i));
            let t = _mm256_mul_pd(sv, _mm256_sub_pd(one, sv));
            _mm256_storeu_pd(po.add(i), _mm256_mul_pd(vscale, t));
            i += 4;
        }
        while i < n {
            out[i] = scale * (s[i] * (1.0 - s[i]));
            i += 1;
        }
    }

    /// `out_i = σ(−z_i)`: 4-lane mirror of [`super::sigmoid_poly`] —
    /// the identical mul/add/sub/div sequence per element (no FMA), so
    /// every lane is bit-identical to the scalar reference.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sigmoid_neg_scan(z: &[f64], out: &mut [f64]) {
        let n = z.len();
        let pz = z.as_ptr();
        let po = out.as_mut_ptr();
        let sign = _mm256_set1_pd(-0.0);
        let arg_min = _mm256_set1_pd(super::SIG_ARG_MIN);
        let inv_ln2 = _mm256_set1_pd(super::EXP_INV_LN2);
        let ln2_hi = _mm256_set1_pd(super::EXP_LN2_HI);
        let ln2_lo = _mm256_set1_pd(super::EXP_LN2_LO);
        let p1 = _mm256_set1_pd(super::EXP_P1);
        let p2 = _mm256_set1_pd(super::EXP_P2);
        let p3 = _mm256_set1_pd(super::EXP_P3);
        let p4 = _mm256_set1_pd(super::EXP_P4);
        let p5 = _mm256_set1_pd(super::EXP_P5);
        let one = _mm256_set1_pd(1.0);
        let two = _mm256_set1_pd(2.0);
        let zero = _mm256_setzero_pd();
        let exp_bias = _mm256_set1_epi64x(1023);
        let mut i = 0;
        while i + 4 <= n {
            let zv = _mm256_loadu_pd(pz.add(i));
            // x = −z; a = clamp(−|x|): −|x| = −|z| is the sign-OR of z
            // (the same single bit op as scalar `-x.abs()`), and MAXPD
            // returns its *second* operand on NaN, so NaN stays NaN —
            // exactly the scalar `if ax < MIN { MIN } else { ax }`.
            let ax = _mm256_or_pd(sign, zv);
            let a = _mm256_max_pd(arg_min, ax);
            // k = round_ties_even(a / ln2): CVTPD2DQ rounds to nearest
            // even under the default MXCSR, matching the scalar cast.
            let k = _mm256_cvtpd_epi32(_mm256_mul_pd(a, inv_ln2));
            let kd = _mm256_cvtepi32_pd(k);
            let hi = _mm256_sub_pd(a, _mm256_mul_pd(kd, ln2_hi));
            let lo = _mm256_mul_pd(kd, ln2_lo);
            let r = _mm256_sub_pd(hi, lo);
            let t = _mm256_mul_pd(r, r);
            // Horner chain with plain mul/add — rounding for rounding
            // the scalar reference.
            let mut p = _mm256_add_pd(p4, _mm256_mul_pd(t, p5));
            p = _mm256_add_pd(p3, _mm256_mul_pd(t, p));
            p = _mm256_add_pd(p2, _mm256_mul_pd(t, p));
            p = _mm256_add_pd(p1, _mm256_mul_pd(t, p));
            let c = _mm256_sub_pd(r, _mm256_mul_pd(t, p));
            let q = _mm256_div_pd(
                _mm256_mul_pd(r, c),
                _mm256_sub_pd(two, c),
            );
            let y = _mm256_sub_pd(
                one,
                _mm256_sub_pd(_mm256_sub_pd(lo, q), hi),
            );
            // e = (y · 2^(k/2)) · 2^(k−k/2): each factor is a normal
            // power of two built directly in the exponent field.
            let k1 = _mm_srai_epi32::<1>(k);
            let k2 = _mm_sub_epi32(k, k1);
            let s1 = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(
                _mm256_add_epi64(_mm256_cvtepi32_epi64(k1), exp_bias),
            ));
            let s2 = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(
                _mm256_add_epi64(_mm256_cvtepi32_epi64(k2), exp_bias),
            ));
            let e = _mm256_mul_pd(_mm256_mul_pd(y, s1), s2);
            // num = 1 where x = −z ≥ 0 ⇔ z ≤ 0 (ordered compare: a NaN
            // lane selects e, like the scalar branch).
            let num = _mm256_blendv_pd(
                e,
                one,
                _mm256_cmp_pd::<_CMP_LE_OQ>(zv, zero),
            );
            _mm256_storeu_pd(
                po.add(i),
                _mm256_div_pd(num, _mm256_add_pd(one, e)),
            );
            i += 4;
        }
        while i < n {
            out[i] = super::sigmoid_poly(-z[i]);
            i += 1;
        }
    }

    /// Bulk superaccumulate with a **vectorized limb scatter**: the
    /// (exponent, mantissa, sign) decompose *and* the 3-chunk limb
    /// split of 4 lanes run on the integer units; only the final
    /// indexed adds stay scalar (data-dependent addresses). All
    /// arithmetic is integer-exact, so the result is **bit-identical**
    /// to `scalar::binned_accumulate` — only throughput differs. A
    /// group containing a non-finite lane falls back to the scalar
    /// slow path for the whole group (safe: integer limb adds
    /// commute, so group-internal order is irrelevant).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn binned_accumulate(
        limbs: &mut [i64; crate::linalg::reduce::LIMBS],
        xs: &[f64],
    ) -> u8 {
        use crate::linalg::reduce::{accumulate_one, propagate_limbs};
        let mut special = 0u8;
        let exp_mask = _mm256_set1_epi64x(0x7ff);
        let frac_mask = _mm256_set1_epi64x((1i64 << 52) - 1);
        let implicit = _mm256_set1_epi64x(1i64 << 52);
        let one = _mm256_set1_epi64x(1);
        // exp.max(1) − 1075 + OFFSET_BIAS = exp.max(1) + 13.
        let bias = _mm256_set1_epi64x(13);
        let low32 = _mm256_set1_epi64x(0xFFFF_FFFF);
        let sh_max = _mm256_set1_epi64x(63);
        let five_bits = _mm256_set1_epi64x(31);
        let zero = _mm256_setzero_si256();
        for chunk in xs.chunks(super::BINNED_CHUNK) {
            let n = chunk.len();
            let p = chunk.as_ptr();
            let mut i = 0;
            while i + 4 <= n {
                let b =
                    _mm256_loadu_si256(p.add(i) as *const __m256i);
                let exp = _mm256_and_si256(
                    _mm256_srli_epi64::<52>(b),
                    exp_mask,
                );
                // Non-finite lanes (exp == 0x7ff): the scalar slow
                // path owns the special semantics for the group.
                let is_special = _mm256_cmpeq_epi64(exp, exp_mask);
                if _mm256_movemask_pd(_mm256_castsi256_pd(is_special))
                    != 0
                {
                    for lane in 0..4 {
                        special |=
                            accumulate_one(limbs, chunk[i + lane]);
                    }
                    i += 4;
                    continue;
                }
                let frac = _mm256_and_si256(b, frac_mask);
                // Subnormal lanes (exp == 0) carry no implicit bit and
                // use the exp = 1 scale; ±0 flows through the vector
                // path as an all-zero scatter — limb-identical to the
                // scalar early return.
                let is_sub = _mm256_cmpeq_epi64(exp, zero);
                let mant = _mm256_or_si256(
                    frac,
                    _mm256_andnot_si256(is_sub, implicit),
                );
                let eadj = _mm256_add_epi64(
                    exp,
                    _mm256_and_si256(is_sub, one),
                );
                // off ∈ [14, 2059] ⇒ limb index j = off/32 ≤ 64 and
                // j + 2 < LIMBS; shift sh = off mod 32.
                let off = _mm256_add_epi64(eadj, bias);
                let j = _mm256_srli_epi64::<5>(off);
                let sh = _mm256_and_si256(off, five_bits);
                // 96-bit split of mant << sh (mant < 2^53, sh < 32):
                // c2 = mant >> (64−sh), written (mant >> (63−sh)) >> 1
                // so the sh = 0 lane shifts by 63+1, not 64.
                let lo = _mm256_sllv_epi64(mant, sh);
                let c0 = _mm256_and_si256(lo, low32);
                let c1 = _mm256_srli_epi64::<32>(lo);
                let c2 = _mm256_srli_epi64::<1>(_mm256_srlv_epi64(
                    mant,
                    _mm256_sub_epi64(sh_max, sh),
                ));
                // Two's-complement negate the chunks of negative lanes
                // (adding −c ≡ the scalar path's subtract).
                let negm = _mm256_cmpgt_epi64(zero, b);
                let c0 =
                    _mm256_sub_epi64(_mm256_xor_si256(c0, negm), negm);
                let c1 =
                    _mm256_sub_epi64(_mm256_xor_si256(c1, negm), negm);
                let c2 =
                    _mm256_sub_epi64(_mm256_xor_si256(c2, negm), negm);
                let mut j_a = [0i64; 4];
                let mut c0_a = [0i64; 4];
                let mut c1_a = [0i64; 4];
                let mut c2_a = [0i64; 4];
                _mm256_storeu_si256(
                    j_a.as_mut_ptr() as *mut __m256i,
                    j,
                );
                _mm256_storeu_si256(
                    c0_a.as_mut_ptr() as *mut __m256i,
                    c0,
                );
                _mm256_storeu_si256(
                    c1_a.as_mut_ptr() as *mut __m256i,
                    c1,
                );
                _mm256_storeu_si256(
                    c2_a.as_mut_ptr() as *mut __m256i,
                    c2,
                );
                for lane in 0..4 {
                    let j = j_a[lane] as usize;
                    limbs[j] += c0_a[lane];
                    limbs[j + 1] += c1_a[lane];
                    limbs[j + 2] += c2_a[lane];
                }
                i += 4;
            }
            while i < n {
                special |= accumulate_one(limbs, chunk[i]);
                i += 1;
            }
            propagate_limbs(limbs);
        }
        if xs.is_empty() {
            propagate_limbs(limbs);
        }
        special
    }

    /// Row-ranged rank-1 accumulate (see `scalar::sym_rank1_upper_rows`):
    /// `block` holds rows `u0..u1` of the matrix; per-entry op order is
    /// identical regardless of the row partition. The full-matrix entry
    /// point is the dispatcher's `sym_rank1_upper`, which calls this
    /// with rows `0..d`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sym_rank1_upper_rows(
        block: &mut [f64],
        d: usize,
        u0: usize,
        u1: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        debug_assert_eq!(block.len(), (u1 - u0) * d);
        let mut b = 0;
        while b + 4 <= samples.len() {
            let (a0, a1, a2, a3) =
                (samples[b], samples[b + 1], samples[b + 2], samples[b + 3]);
            let (h0, h1, h2, h3) = (h[b], h[b + 1], h[b + 2], h[b + 3]);
            let (p0, p1, p2, p3) =
                (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr());
            for u in u0..u1 {
                let s0 = h0 * a0[u];
                let s1 = h1 * a1[u];
                let s2 = h2 * a2[u];
                let s3 = h3 * a3[u];
                let c0 = _mm256_set1_pd(s0);
                let c1 = _mm256_set1_pd(s1);
                let c2 = _mm256_set1_pd(s2);
                let c3 = _mm256_set1_pd(s3);
                let row = block.as_mut_ptr().add((u - u0) * d);
                let mut v = u;
                while v + 4 <= d {
                    let mut acc = _mm256_loadu_pd(row.add(v));
                    acc = _mm256_fmadd_pd(c0, _mm256_loadu_pd(p0.add(v)), acc);
                    acc = _mm256_fmadd_pd(c1, _mm256_loadu_pd(p1.add(v)), acc);
                    acc = _mm256_fmadd_pd(c2, _mm256_loadu_pd(p2.add(v)), acc);
                    acc = _mm256_fmadd_pd(c3, _mm256_loadu_pd(p3.add(v)), acc);
                    _mm256_storeu_pd(row.add(v), acc);
                    v += 4;
                }
                while v < d {
                    *row.add(v) +=
                        s0 * a0[v] + s1 * a1[v] + s2 * a2[v] + s3 * a3[v];
                    v += 1;
                }
            }
            b += 4;
        }
        while b < samples.len() {
            let a = samples[b];
            let hb = h[b];
            let pa = a.as_ptr();
            for u in u0..u1 {
                let s = hb * a[u];
                let c = _mm256_set1_pd(s);
                let row = block.as_mut_ptr().add((u - u0) * d);
                let mut v = u;
                while v + 4 <= d {
                    let acc = _mm256_fmadd_pd(
                        c,
                        _mm256_loadu_pd(pa.add(v)),
                        _mm256_loadu_pd(row.add(v)),
                    );
                    _mm256_storeu_pd(row.add(v), acc);
                    v += 4;
                }
                while v < d {
                    *row.add(v) += s * a[v];
                    v += 1;
                }
            }
            b += 1;
        }
    }
}

// ---------------------------------------------------------------------
// AVX-512 path (x86-64 + rustc ≥ 1.89 only; see `build.rs`). Every
// kernel is constructed to be bit-identical to the AVX2 tier: 512-bit
// accumulators are lane-concatenations of AVX2's 256-bit accumulator
// pairs, reductions extract those halves and finish with the exact AVX2
// combine tree, and FMA coverage matches AVX2 element for element (an
// 8-wide loop, one 4-wide step, the same scalar tail). Logical ops on
// 512-bit floats go through the integer domain (`_mm512_and_epi64` /
// `_mm512_or_epi64`) so only AVX512F is required — no DQ/VL.
// ---------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", fednl_avx512))]
mod avx512 {
    use core::arch::x86_64::*;

    /// AVX2's horizontal sum, bit for bit: (l0 + l1) + (l2 + l3).
    #[inline]
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    unsafe fn hsum256(v: __m256d) -> f64 {
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), v);
        (buf[0] + buf[1]) + (buf[2] + buf[3])
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // z0 = acc0 ‖ acc1, z1 = acc2 ‖ acc3 of the AVX2 kernel: the
        // 16-per-iteration partition assigns the same elements to the
        // same accumulator lanes, so the reduction below reproduces
        // the AVX2 sum exactly.
        let mut z0 = _mm512_setzero_pd();
        let mut z1 = _mm512_setzero_pd();
        let mut i = 0;
        while i + 16 <= n {
            z0 = _mm512_fmadd_pd(
                _mm512_loadu_pd(pa.add(i)),
                _mm512_loadu_pd(pb.add(i)),
                z0,
            );
            z1 = _mm512_fmadd_pd(
                _mm512_loadu_pd(pa.add(i + 8)),
                _mm512_loadu_pd(pb.add(i + 8)),
                z1,
            );
            i += 16;
        }
        let mut acc0 = _mm512_extractf64x4_pd::<0>(z0);
        let acc1 = _mm512_extractf64x4_pd::<1>(z0);
        let acc2 = _mm512_extractf64x4_pd::<0>(z1);
        let acc3 = _mm512_extractf64x4_pd::<1>(z1);
        while i + 4 <= n {
            acc0 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i)),
                _mm256_loadu_pd(pb.add(i)),
                acc0,
            );
            i += 4;
        }
        let acc = _mm256_add_pd(
            _mm256_add_pd(acc0, acc1),
            _mm256_add_pd(acc2, acc3),
        );
        let mut s = hsum256(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let va8 = _mm512_set1_pd(alpha);
        let va4 = _mm256_set1_pd(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let y0 = _mm512_fmadd_pd(
                va8,
                _mm512_loadu_pd(px.add(i)),
                _mm512_loadu_pd(py.add(i)),
            );
            _mm512_storeu_pd(py.add(i), y0);
            i += 8;
        }
        // One 4-wide step keeps the FMA-covered element set identical
        // to AVX2's (⌊n/4⌋·4) before the mul+add scalar tail.
        while i + 4 <= n {
            let y0 = _mm256_fmadd_pd(
                va4,
                _mm256_loadu_pd(px.add(i)),
                _mm256_loadu_pd(py.add(i)),
            );
            _mm256_storeu_pd(py.add(i), y0);
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn add_scaled(
        a: &[f64],
        alpha: f64,
        b: &[f64],
        out: &mut [f64],
    ) {
        let n = a.len();
        let va8 = _mm512_set1_pd(alpha);
        let va4 = _mm256_set1_pd(alpha);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let o = _mm512_fmadd_pd(
                va8,
                _mm512_loadu_pd(pb.add(i)),
                _mm512_loadu_pd(pa.add(i)),
            );
            _mm512_storeu_pd(po.add(i), o);
            i += 8;
        }
        while i + 4 <= n {
            let o = _mm256_fmadd_pd(
                va4,
                _mm256_loadu_pd(pb.add(i)),
                _mm256_loadu_pd(pa.add(i)),
            );
            _mm256_storeu_pd(po.add(i), o);
            i += 4;
        }
        while i < n {
            out[i] = a[i] + alpha * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn abs_max(x: &[f64]) -> f64 {
        let n = x.len();
        let px = x.as_ptr();
        let mask = _mm512_set1_epi64(i64::MAX);
        let mut m = _mm512_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm512_castsi512_pd(_mm512_and_epi64(
                mask,
                _mm512_castpd_si512(_mm512_loadu_pd(px.add(i))),
            ));
            // VMAXPD returns the second operand on NaN — accumulator
            // there, so NaN inputs stay transparent (max over the
            // non-NaN |x| multiset is grouping-invariant, hence equal
            // to the AVX2 result despite the wider lanes).
            m = _mm512_max_pd(v, m);
            i += 8;
        }
        let mut buf = [0.0f64; 8];
        _mm512_storeu_pd(buf.as_mut_ptr(), m);
        let mut s = buf[0];
        for &b in &buf[1..] {
            s = s.max(b);
        }
        while i < n {
            s = s.max(x[i].abs());
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
        // Elementwise (two roundings per element) — identical at any
        // lane width, so no 4-wide step is needed.
        let n = v.len();
        let (pw, pv) = (w.as_ptr(), v.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let vv = _mm512_loadu_pd(pv.add(i));
            let e = _mm512_mul_pd(
                _mm512_loadu_pd(pw.add(i)),
                _mm512_mul_pd(vv, vv),
            );
            _mm512_storeu_pd(po.add(i), e);
            i += 8;
        }
        while i < n {
            out[i] = w[i] * (v[i] * v[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
        let n = v.len();
        let (pw, pv) = (w.as_ptr(), v.as_ptr());
        // z = acc0 ‖ acc1 of the AVX2 kernel (8-per-iteration).
        let mut z = _mm512_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v0 = _mm512_loadu_pd(pv.add(i));
            z = _mm512_fmadd_pd(
                _mm512_mul_pd(_mm512_loadu_pd(pw.add(i)), v0),
                v0,
                z,
            );
            i += 8;
        }
        let mut acc0 = _mm512_extractf64x4_pd::<0>(z);
        let acc1 = _mm512_extractf64x4_pd::<1>(z);
        while i + 4 <= n {
            let v0 = _mm256_loadu_pd(pv.add(i));
            acc0 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), v0),
                v0,
                acc0,
            );
            i += 4;
        }
        let mut s = hsum256(_mm256_add_pd(acc0, acc1));
        while i < n {
            s += w[i] * (v[i] * v[i]);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn sigmoid_variance_scan(
        s: &[f64],
        scale: f64,
        out: &mut [f64],
    ) {
        let n = s.len();
        let vscale = _mm512_set1_pd(scale);
        let one = _mm512_set1_pd(1.0);
        let ps = s.as_ptr();
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let sv = _mm512_loadu_pd(ps.add(i));
            let t = _mm512_mul_pd(sv, _mm512_sub_pd(one, sv));
            _mm512_storeu_pd(po.add(i), _mm512_mul_pd(vscale, t));
            i += 8;
        }
        while i < n {
            out[i] = scale * (s[i] * (1.0 - s[i]));
            i += 1;
        }
    }

    /// 8-lane mirror of [`super::sigmoid_poly`] — identical per-lane
    /// operation sequence to the scalar/AVX2 paths (elementwise, no
    /// cross-lane reduction), so bit-identical at any width.
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn sigmoid_neg_scan(z: &[f64], out: &mut [f64]) {
        let n = z.len();
        let pz = z.as_ptr();
        let po = out.as_mut_ptr();
        let sign = _mm512_set1_epi64((-0.0f64).to_bits() as i64);
        let arg_min = _mm512_set1_pd(super::SIG_ARG_MIN);
        let inv_ln2 = _mm512_set1_pd(super::EXP_INV_LN2);
        let ln2_hi = _mm512_set1_pd(super::EXP_LN2_HI);
        let ln2_lo = _mm512_set1_pd(super::EXP_LN2_LO);
        let p1 = _mm512_set1_pd(super::EXP_P1);
        let p2 = _mm512_set1_pd(super::EXP_P2);
        let p3 = _mm512_set1_pd(super::EXP_P3);
        let p4 = _mm512_set1_pd(super::EXP_P4);
        let p5 = _mm512_set1_pd(super::EXP_P5);
        let one = _mm512_set1_pd(1.0);
        let two = _mm512_set1_pd(2.0);
        let zero = _mm512_setzero_pd();
        let exp_bias = _mm512_set1_epi64(1023);
        let mut i = 0;
        while i + 8 <= n {
            let zv = _mm512_loadu_pd(pz.add(i));
            // −|z| via sign-OR in the integer domain (AVX512F only).
            let ax = _mm512_castsi512_pd(_mm512_or_epi64(
                sign,
                _mm512_castpd_si512(zv),
            ));
            let a = _mm512_max_pd(arg_min, ax);
            let k = _mm512_cvtpd_epi32(_mm512_mul_pd(a, inv_ln2));
            let kd = _mm512_cvtepi32_pd(k);
            let hi = _mm512_sub_pd(a, _mm512_mul_pd(kd, ln2_hi));
            let lo = _mm512_mul_pd(kd, ln2_lo);
            let r = _mm512_sub_pd(hi, lo);
            let t = _mm512_mul_pd(r, r);
            let mut p = _mm512_add_pd(p4, _mm512_mul_pd(t, p5));
            p = _mm512_add_pd(p3, _mm512_mul_pd(t, p));
            p = _mm512_add_pd(p2, _mm512_mul_pd(t, p));
            p = _mm512_add_pd(p1, _mm512_mul_pd(t, p));
            let c = _mm512_sub_pd(r, _mm512_mul_pd(t, p));
            let q = _mm512_div_pd(
                _mm512_mul_pd(r, c),
                _mm512_sub_pd(two, c),
            );
            let y = _mm512_sub_pd(
                one,
                _mm512_sub_pd(_mm512_sub_pd(lo, q), hi),
            );
            let k1 = _mm256_srai_epi32::<1>(k);
            let k2 = _mm256_sub_epi32(k, k1);
            let s1 = _mm512_castsi512_pd(_mm512_slli_epi64::<52>(
                _mm512_add_epi64(_mm512_cvtepi32_epi64(k1), exp_bias),
            ));
            let s2 = _mm512_castsi512_pd(_mm512_slli_epi64::<52>(
                _mm512_add_epi64(_mm512_cvtepi32_epi64(k2), exp_bias),
            ));
            let e = _mm512_mul_pd(_mm512_mul_pd(y, s1), s2);
            let le = _mm512_cmp_pd_mask::<_CMP_LE_OQ>(zv, zero);
            let num = _mm512_mask_blend_pd(le, e, one);
            _mm512_storeu_pd(
                po.add(i),
                _mm512_div_pd(num, _mm512_add_pd(one, e)),
            );
            i += 8;
        }
        while i < n {
            out[i] = super::sigmoid_poly(-z[i]);
            i += 1;
        }
    }

    /// 8-lane variant of the AVX2 vectorized limb scatter (see
    /// `avx2::binned_accumulate`); integer-exact, limb-identical to
    /// every other tier.
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn binned_accumulate(
        limbs: &mut [i64; crate::linalg::reduce::LIMBS],
        xs: &[f64],
    ) -> u8 {
        use crate::linalg::reduce::{accumulate_one, propagate_limbs};
        let mut special = 0u8;
        let exp_mask = _mm512_set1_epi64(0x7ff);
        let frac_mask = _mm512_set1_epi64((1i64 << 52) - 1);
        let implicit = _mm512_set1_epi64(1i64 << 52);
        let one = _mm512_set1_epi64(1);
        let bias = _mm512_set1_epi64(13);
        let low32 = _mm512_set1_epi64(0xFFFF_FFFF);
        let sh_max = _mm512_set1_epi64(63);
        let five_bits = _mm512_set1_epi64(31);
        let zero = _mm512_setzero_si512();
        for chunk in xs.chunks(super::BINNED_CHUNK) {
            let n = chunk.len();
            let p = chunk.as_ptr();
            let mut i = 0;
            while i + 8 <= n {
                // Bit-preserving integer load via the pd move (the
                // `_mm512_loadu_si512` signature varies across stdarch
                // versions; this form does not).
                let b = _mm512_castpd_si512(_mm512_loadu_pd(p.add(i)));
                let exp = _mm512_and_epi64(
                    _mm512_srli_epi64::<52>(b),
                    exp_mask,
                );
                if _mm512_cmpeq_epi64_mask(exp, exp_mask) != 0 {
                    for lane in 0..8 {
                        special |=
                            accumulate_one(limbs, chunk[i + lane]);
                    }
                    i += 8;
                    continue;
                }
                let frac = _mm512_and_epi64(b, frac_mask);
                let not_sub = _mm512_cmpneq_epi64_mask(exp, zero);
                let mant =
                    _mm512_mask_or_epi64(frac, not_sub, frac, implicit);
                let eadj = _mm512_max_epi64(exp, one);
                let off = _mm512_add_epi64(eadj, bias);
                let j = _mm512_srli_epi64::<5>(off);
                let sh = _mm512_and_epi64(off, five_bits);
                let lo = _mm512_sllv_epi64(mant, sh);
                let c0 = _mm512_and_epi64(lo, low32);
                let c1 = _mm512_srli_epi64::<32>(lo);
                let c2 = _mm512_srli_epi64::<1>(_mm512_srlv_epi64(
                    mant,
                    _mm512_sub_epi64(sh_max, sh),
                ));
                let m_neg = _mm512_cmplt_epi64_mask(b, zero);
                let c0 = _mm512_mask_sub_epi64(c0, m_neg, zero, c0);
                let c1 = _mm512_mask_sub_epi64(c1, m_neg, zero, c1);
                let c2 = _mm512_mask_sub_epi64(c2, m_neg, zero, c2);
                let mut j_a = [0i64; 8];
                let mut c0_a = [0i64; 8];
                let mut c1_a = [0i64; 8];
                let mut c2_a = [0i64; 8];
                _mm512_storeu_pd(
                    j_a.as_mut_ptr() as *mut f64,
                    _mm512_castsi512_pd(j),
                );
                _mm512_storeu_pd(
                    c0_a.as_mut_ptr() as *mut f64,
                    _mm512_castsi512_pd(c0),
                );
                _mm512_storeu_pd(
                    c1_a.as_mut_ptr() as *mut f64,
                    _mm512_castsi512_pd(c1),
                );
                _mm512_storeu_pd(
                    c2_a.as_mut_ptr() as *mut f64,
                    _mm512_castsi512_pd(c2),
                );
                for lane in 0..8 {
                    let j = j_a[lane] as usize;
                    limbs[j] += c0_a[lane];
                    limbs[j + 1] += c1_a[lane];
                    limbs[j + 2] += c2_a[lane];
                }
                i += 8;
            }
            while i < n {
                special |= accumulate_one(limbs, chunk[i]);
                i += 1;
            }
            propagate_limbs(limbs);
        }
        if xs.is_empty() {
            propagate_limbs(limbs);
        }
        special
    }

    /// Row-ranged rank-1 accumulate: per-element FMA chain order is
    /// identical to AVX2 (c0 → c1 → c2 → c3 per column), and the
    /// vector-covered column set matches AVX2's ⌊(d−u)/4⌋·4 via the
    /// 8-then-4-then-scalar structure.
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub unsafe fn sym_rank1_upper_rows(
        block: &mut [f64],
        d: usize,
        u0: usize,
        u1: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        debug_assert_eq!(block.len(), (u1 - u0) * d);
        let mut b = 0;
        while b + 4 <= samples.len() {
            let (a0, a1, a2, a3) =
                (samples[b], samples[b + 1], samples[b + 2], samples[b + 3]);
            let (h0, h1, h2, h3) = (h[b], h[b + 1], h[b + 2], h[b + 3]);
            let (p0, p1, p2, p3) =
                (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr());
            for u in u0..u1 {
                let s0 = h0 * a0[u];
                let s1 = h1 * a1[u];
                let s2 = h2 * a2[u];
                let s3 = h3 * a3[u];
                let w0 = _mm512_set1_pd(s0);
                let w1 = _mm512_set1_pd(s1);
                let w2 = _mm512_set1_pd(s2);
                let w3 = _mm512_set1_pd(s3);
                let c0 = _mm256_set1_pd(s0);
                let c1 = _mm256_set1_pd(s1);
                let c2 = _mm256_set1_pd(s2);
                let c3 = _mm256_set1_pd(s3);
                let row = block.as_mut_ptr().add((u - u0) * d);
                let mut v = u;
                while v + 8 <= d {
                    let mut acc = _mm512_loadu_pd(row.add(v));
                    acc = _mm512_fmadd_pd(
                        w0,
                        _mm512_loadu_pd(p0.add(v)),
                        acc,
                    );
                    acc = _mm512_fmadd_pd(
                        w1,
                        _mm512_loadu_pd(p1.add(v)),
                        acc,
                    );
                    acc = _mm512_fmadd_pd(
                        w2,
                        _mm512_loadu_pd(p2.add(v)),
                        acc,
                    );
                    acc = _mm512_fmadd_pd(
                        w3,
                        _mm512_loadu_pd(p3.add(v)),
                        acc,
                    );
                    _mm512_storeu_pd(row.add(v), acc);
                    v += 8;
                }
                while v + 4 <= d {
                    let mut acc = _mm256_loadu_pd(row.add(v));
                    acc = _mm256_fmadd_pd(c0, _mm256_loadu_pd(p0.add(v)), acc);
                    acc = _mm256_fmadd_pd(c1, _mm256_loadu_pd(p1.add(v)), acc);
                    acc = _mm256_fmadd_pd(c2, _mm256_loadu_pd(p2.add(v)), acc);
                    acc = _mm256_fmadd_pd(c3, _mm256_loadu_pd(p3.add(v)), acc);
                    _mm256_storeu_pd(row.add(v), acc);
                    v += 4;
                }
                while v < d {
                    *row.add(v) +=
                        s0 * a0[v] + s1 * a1[v] + s2 * a2[v] + s3 * a3[v];
                    v += 1;
                }
            }
            b += 4;
        }
        while b < samples.len() {
            let a = samples[b];
            let hb = h[b];
            let pa = a.as_ptr();
            for u in u0..u1 {
                let s = hb * a[u];
                let w = _mm512_set1_pd(s);
                let c = _mm256_set1_pd(s);
                let row = block.as_mut_ptr().add((u - u0) * d);
                let mut v = u;
                while v + 8 <= d {
                    let acc = _mm512_fmadd_pd(
                        w,
                        _mm512_loadu_pd(pa.add(v)),
                        _mm512_loadu_pd(row.add(v)),
                    );
                    _mm512_storeu_pd(row.add(v), acc);
                    v += 8;
                }
                while v + 4 <= d {
                    let acc = _mm256_fmadd_pd(
                        c,
                        _mm256_loadu_pd(pa.add(v)),
                        _mm256_loadu_pd(row.add(v)),
                    );
                    _mm256_storeu_pd(row.add(v), acc);
                    v += 4;
                }
                while v < d {
                    *row.add(v) += s * a[v];
                    v += 1;
                }
            }
            b += 1;
        }
    }
}

// Scalar-vs-dispatched equivalence properties live in
// `tests/simd_kernels.rs` (tier-1); only dispatch mechanics are unit
// tested here.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_resolves() {
        let name = isa_name();
        assert!(
            name == "avx512" || name == "avx2" || name == "scalar",
            "unexpected isa {name:?}"
        );
        // Second call hits the cache and must agree.
        assert_eq!(isa_name(), name);
        // The dispatched tier must report as available, and the
        // pinned-tier names must round-trip.
        for which in Isa::ALL {
            assert_eq!(
                which.name(),
                match which {
                    Isa::Scalar => "scalar",
                    Isa::Avx2 => "avx2",
                    Isa::Avx512 => "avx512",
                }
            );
            if which.name() == name {
                assert!(isa_available(which));
            }
        }
    }

    #[test]
    fn sigmoid_poly_edges() {
        // Exact values the accuracy budget pins down (module docs);
        // the dense ulp sweep lives in tests/simd_kernels.rs.
        assert_eq!(sigmoid_poly(0.0).to_bits(), 0.5f64.to_bits());
        assert_eq!(sigmoid_poly(-0.0).to_bits(), 0.5f64.to_bits());
        assert_eq!(sigmoid_poly(-746.0), 0.0);
        assert_eq!(sigmoid_poly(-1e4), 0.0);
        assert_eq!(sigmoid_poly(746.0), 1.0);
        assert_eq!(sigmoid_poly(1e4), 1.0);
        assert_eq!(sigmoid_poly(f64::NEG_INFINITY), 0.0);
        assert_eq!(sigmoid_poly(f64::INFINITY), 1.0);
        assert!(sigmoid_poly(f64::NAN).is_nan());
        // Symmetry within one ulp: σ(x) + σ(−x) = 1.
        for x in [-30.0, -2.0, 0.7, 13.5] {
            let s = sigmoid_poly(x) + sigmoid_poly(-x);
            assert!((s - 1.0).abs() < 1e-15, "x={x}: {s}");
        }
    }

    #[test]
    fn gather_window_wraps() {
        let src: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut out = Vec::new();
        gather_window(&src, 7, 5, &mut out);
        assert_eq!(out, vec![7.0, 8.0, 9.0, 0.0, 1.0]);
        gather_window(&src, 0, 3, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn abs_max_ignores_nan_like_scalar() {
        // VMAXPD operand order keeps the accumulator on NaN — both
        // paths must treat NaN inputs as transparent.
        let mut x = vec![5.0, -1.0, 2.0, 3.0, f64::NAN, 0.5, -0.25, 1.0];
        x.extend(std::iter::repeat(0.1).take(9)); // force a scalar tail
        assert_eq!(abs_max(&x), 5.0);
        assert_eq!(scalar::abs_max(&x), 5.0);
    }

    #[test]
    fn triangle_row_blocks_partition_properties() {
        for (d, t) in [(1usize, 1usize), (5, 2), (37, 4), (301, 8), (8, 16)] {
            let t = t.min(d);
            let b = triangle_row_blocks(d, t);
            assert_eq!(b.len(), t + 1);
            assert_eq!(b[0], 0);
            assert_eq!(b[t], d);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
            // Deterministic in (d, t).
            assert_eq!(b, triangle_row_blocks(d, t));
        }
        // Balance: no block should carry more than ~2× the ideal
        // triangle area (coarse bound; exact balance is impossible with
        // whole rows).
        let d = 301;
        let t = 8;
        let b = triangle_row_blocks(d, t);
        let total = d * (d + 1) / 2;
        for w in b.windows(2) {
            let area: usize = (w[0]..w[1]).map(|u| d - u).sum();
            assert!(area * t <= total * 2, "block {w:?} area {area}");
        }
    }
}

//! Multi-node TCP integration: real sockets on loopback, the full
//! unified wire protocol, all three algorithms through the single round
//! engine — and trajectory equivalence with the in-process reference
//! (the wire codec is bit-exact for f64).

use fednl::algorithms::{
    run_fednl, run_fednl_ls_pool, run_fednl_pool, run_fednl_pp,
    run_fednl_pp_pool, ClientState, LineSearchParams, Options,
    PPClientState,
};
use fednl::compressors::by_name;
use fednl::coordinator::ClientPool;
use fednl::data::{generate_synthetic, Dataset, LibsvmSample, SynthSpec};
use fednl::net::client::ClientMode;
use fednl::net::run_client;
use fednl::net::server::Bound;
use fednl::net::wire;
use fednl::oracle::LogisticOracle;

fn dataset(d_raw: usize, n: usize, seed: u64) -> Dataset {
    let spec =
        SynthSpec { d_raw, n_samples: n, density: 0.5, noise: 1.0, seed };
    let synth = generate_synthetic(&spec);
    let samples: Vec<LibsvmSample> = synth
        .labels
        .iter()
        .zip(&synth.rows)
        .map(|(l, r)| LibsvmSample { label: *l, features: r.clone() })
        .collect();
    let mut ds = Dataset::from_libsvm(&samples, d_raw);
    ds.reshuffle(seed);
    ds
}

fn spawn_clients(
    ds: &Dataset,
    n: usize,
    comp: &str,
    addr: &str,
    pp: bool,
) -> Vec<std::thread::JoinHandle<anyhow::Result<(u64, u64)>>> {
    let d = ds.d;
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .map(|shard| {
            let addr = addr.to_string();
            let comp = by_name(comp, d, 8, 100 + shard.client_id as u64).unwrap();
            std::thread::spawn(move || {
                let id = shard.client_id;
                let oracle = Box::new(LogisticOracle::new(shard, 1e-3));
                let mode = if pp {
                    ClientMode::PP(PPClientState::new(
                        id,
                        oracle,
                        comp,
                        None,
                        &vec![0.0; d],
                    ))
                } else {
                    ClientMode::FedNL(ClientState::new(id, oracle, comp, None))
                };
                run_client(&addr, id, mode)
            })
        })
        .collect()
}

#[test]
fn tcp_fednl_matches_in_process_reference() {
    let ds = dataset(9, 150, 7);
    let d = ds.d;
    const N: usize = 5;
    let opts = Options { rounds: 25, track_loss: true, ..Default::default() };

    // Reference: sequential in-process (identical seeds).
    let mut ref_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("randseqk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_ref = run_fednl(&mut ref_clients, &opts, vec![0.0; d]);

    // TCP run.
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "randseqk", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let t_tcp = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "tcp");
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        // f64 wire encoding is bit-exact; trajectories must be identical.
        assert_eq!(a.grad_norm, b.grad_norm, "round {}", a.round);
        assert_eq!(a.loss, b.loss);
    }
    assert!(t_tcp.last_grad_norm() < 1e-8);
}

#[test]
fn tcp_fednl_ls_converges() {
    let ds = dataset(8, 120, 8);
    let d = ds.d;
    const N: usize = 4;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "toplek", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let opts = Options { rounds: 40, ..Default::default() };
    let t = run_fednl_ls_pool(
        &mut pool,
        &opts,
        &LineSearchParams::default(),
        vec![0.0; d],
        "tcp-ls",
    );
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert!(t.last_grad_norm() < 1e-8, "{}", t.last_grad_norm());
}

#[test]
fn tcp_fednl_pp_matches_in_process() {
    let ds = dataset(7, 120, 9);
    let d = ds.d;
    const N: usize = 4;
    let opts = Options { rounds: 60, ..Default::default() };

    let mut ref_pps: Vec<PPClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            PPClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
                &vec![0.0; d],
            )
        })
        .collect();
    let t_ref = run_fednl_pp(&mut ref_pps, &opts, 2, 77, vec![0.0; d]);

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "topk", &addr, true);
    let mut pool = bound.accept(N).unwrap();
    let t_tcp = run_fednl_pp_pool(
        &mut pool,
        &opts,
        2,
        77,
        vec![0.0; d],
        "tcp-pp",
    );
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(a.grad_norm, b.grad_norm, "round {}", a.round);
    }
    assert!(t_tcp.last_grad_norm() < 1e-6);
}

#[test]
fn logical_byte_accounting_matches_transport_exactly() {
    // Satellite fix: `ClientMsg::wire_bytes()` and the drivers' frame
    // size helpers are exact framed sizes, so an in-process run's
    // logical byte counts must equal the TCP transport's metered
    // counts up to the connection handshake, which the round loop does
    // not model: one REGISTER frame per client (up) and the SET_ALPHA
    // command (down) / ACK echo (up) pair.
    let ds = dataset(8, 120, 12);
    let d = ds.d;
    const N: usize = 4;
    let opts = Options {
        rounds: 8,
        track_loss: true,
        warm_start: true,
        ..Default::default()
    };

    let mut ref_clients: Vec<ClientState> = ds
        .split_even(N)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name("topk", d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect();
    let t_ref = run_fednl(&mut ref_clients, &opts, vec![0.0; d]);

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "topk", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let t_tcp = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "tcp-bytes");
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // Per client: one REGISTER frame + one ACK echo up, one SET_ALPHA
    // command down.
    let handshake_up =
        (wire::register_frame_bytes() + wire::scalar_frame_bytes())
            * N as u64;
    let handshake_down = wire::scalar_frame_bytes() * N as u64;
    assert_eq!(t_ref.records.len(), t_tcp.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_tcp.records) {
        assert_eq!(
            b.bytes_up,
            a.bytes_up + handshake_up,
            "round {}: logical up {} vs metered {}",
            a.round,
            a.bytes_up,
            b.bytes_up
        );
        assert_eq!(
            b.bytes_down,
            a.bytes_down + handshake_down,
            "round {}: logical down {} vs metered {}",
            a.round,
            a.bytes_down,
            b.bytes_down
        );
    }
}

#[test]
fn transport_bytes_metered() {
    let ds = dataset(6, 80, 10);
    let d = ds.d;
    const N: usize = 3;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let handles = spawn_clients(&ds, N, "randk", &addr, false);
    let mut pool = bound.accept(N).unwrap();
    let opts = Options { rounds: 5, ..Default::default() };
    let t = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "meter");
    let (up, down) = pool.transport_bytes().unwrap();
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    // Real socket-level byte counts: nonzero, and up-dominated (Hessian
    // updates + gradients vs broadcast x).
    assert!(up > 0 && down > 0);
    assert!(up > down, "up {up} ≤ down {down}");
    assert_eq!(t.records.len(), 5);
}

#[test]
fn duplicate_client_id_rejected() {
    let ds = dataset(5, 40, 11);
    let d = ds.d;
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    // Two clients both claiming id 0.
    let mk = |_i: usize| {
        let sh = ds.split_even(2).unwrap().remove(0);
        let addr = addr.clone();
        let comp = by_name("identity", d, 8, 0).unwrap();
        std::thread::spawn(move || {
            let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
            run_client(
                &addr,
                0,
                ClientMode::FedNL(ClientState::new(0, oracle, comp, None)),
            )
        })
    };
    let h1 = mk(0);
    let h2 = mk(1);
    let res = bound.accept(2);
    assert!(res.is_err(), "duplicate registration must fail");
    // The client threads will error out when the master drops; ignore.
    let _ = h1.join();
    let _ = h2.join();
}

//! Wall-clock timing (paper component `timers`).
//!
//! The paper's measurement protocol (Appendix G.3) takes the minimum of
//! repeated launches on a frequency-pinned CPU; [`TimerStats`] mirrors
//! that by tracking min/mean/median over samples.

use std::time::Instant;

/// A simple stopwatch over `std::time::Instant`.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start a new stopwatch.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed since start.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let t = self.elapsed_secs();
        self.start = Instant::now();
        t
    }
}

/// Aggregate statistics over repeated timing samples.
#[derive(Debug, Clone, Default)]
pub struct TimerStats {
    samples: Vec<f64>,
}

impl TimerStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    /// Time `f` once and record it; returns `f`'s output.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.record(sw.elapsed_secs());
        out
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0f64, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    pub fn median(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn stats_basic() {
        let mut st = TimerStats::new();
        for v in [3.0, 1.0, 2.0] {
            st.record(v);
        }
        assert_eq!(st.count(), 3);
        assert_eq!(st.min(), 1.0);
        assert_eq!(st.max(), 3.0);
        assert!((st.mean() - 2.0).abs() < 1e-12);
        assert!((st.median() - 2.0).abs() < 1e-12);
        assert!((st.stddev() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_even_median() {
        let mut st = TimerStats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            st.record(v);
        }
        assert!((st.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn time_records() {
        let mut st = TimerStats::new();
        let out = st.time(|| 41 + 1);
        assert_eq!(out, 42);
        assert_eq!(st.count(), 1);
    }
}

//! Readiness-transport integration: the epoll-driven `EventPool`
//! master and the client-side multiplexer over real loopback sockets.
//!
//! The headline invariants of the event transport:
//! * trajectories are **bit-identical** to the blocking transports
//!   (`RemotePool`) and the in-process reference under the same seed —
//!   mixed plain/mux topologies included;
//! * faults compose: the same `FaultPlan` under a quorum policy yields
//!   bit-identical runs on the readiness transport;
//! * it scales: ≥10k multiplexed clients register through one master
//!   socket loop at a few bytes of idle bookkeeping per client.

#![cfg(unix)]

use fednl::algorithms::{
    run_fednl, run_fednl_ls_pool, run_fednl_pool, run_fednl_pp_pool,
    ClientState, LineSearchParams, OnMissing, Options, PPClientState,
    RoundPolicy,
};
use fednl::compressors::by_name;
use fednl::coordinator::{
    ClientPool, CorruptMode, FaultPlan, FaultPool, SeqPool,
};
use fednl::data::{generate_synthetic, Dataset, LibsvmSample, SynthSpec};
use fednl::net::client::ClientMode;
use fednl::net::server::Bound;
use fednl::net::{run_client, run_mux_clients, EventPool, MuxReport};
use fednl::oracle::LogisticOracle;

fn dataset(d_raw: usize, n: usize, seed: u64) -> Dataset {
    let spec = SynthSpec {
        d_raw,
        n_samples: n,
        density: 0.5,
        noise: 1.0,
        label_bias: 0.0,
        seed,
    };
    let synth = generate_synthetic(&spec);
    let samples: Vec<LibsvmSample> = synth
        .labels
        .iter()
        .zip(&synth.rows)
        .map(|(l, r)| LibsvmSample { label: *l, features: r.clone() })
        .collect();
    let mut ds = Dataset::from_libsvm(&samples, d_raw);
    ds.reshuffle(seed);
    ds
}

fn fednl_clients(ds: &Dataset, n: usize, comp: &str) -> Vec<ClientState> {
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            ClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name(comp, ds.d, 8, 100 + id as u64).unwrap(),
                None,
            )
        })
        .collect()
}

fn pp_clients(
    ds: &Dataset,
    n: usize,
    comp: &str,
    x0: &[f64],
) -> Vec<PPClientState> {
    ds.split_even(n)
        .unwrap()
        .into_iter()
        .map(|sh| {
            let id = sh.client_id;
            PPClientState::new(
                id,
                Box::new(LogisticOracle::new(sh, 1e-3)),
                by_name(comp, ds.d, 8, 100 + id as u64).unwrap(),
                None,
                x0,
            )
        })
        .collect()
}

/// Spawn a mixed topology against `addr`: clients with ids covered by
/// `mux_groups` (contiguous `(gid, lo, hi)` ranges) are hosted by one
/// mux thread per group; every other id gets a plain blocking client
/// thread — exactly the processes `fednl client [--mux N]` would run.
#[allow(clippy::type_complexity)]
fn spawn_mixed(
    ds: &Dataset,
    n: usize,
    comp: &str,
    addr: &str,
    pp: bool,
    mux_groups: &[(u32, usize, usize)],
) -> (
    Vec<std::thread::JoinHandle<anyhow::Result<MuxReport>>>,
    Vec<std::thread::JoinHandle<anyhow::Result<(u64, u64)>>>,
) {
    let d = ds.d;
    let x0 = vec![0.0; d];
    let mut fednl_by_id: Vec<Option<ClientState>> = Vec::new();
    let mut pp_by_id: Vec<Option<PPClientState>> = Vec::new();
    if pp {
        pp_by_id = pp_clients(ds, n, comp, &x0).into_iter().map(Some).collect();
    } else {
        fednl_by_id = fednl_clients(ds, n, comp).into_iter().map(Some).collect();
    }
    let mut muxed = vec![false; n];
    let mut mux_handles = Vec::new();
    for &(gid, lo, hi) in mux_groups {
        let addr = addr.to_string();
        for slot in lo..hi {
            muxed[slot] = true;
        }
        if pp {
            let mut group: Vec<PPClientState> = (lo..hi)
                .map(|i| pp_by_id[i].take().unwrap())
                .collect();
            mux_handles.push(std::thread::spawn(move || {
                run_mux_clients(&mut group, gid, &addr)
            }));
        } else {
            let mut group: Vec<ClientState> = (lo..hi)
                .map(|i| fednl_by_id[i].take().unwrap())
                .collect();
            mux_handles.push(std::thread::spawn(move || {
                run_mux_clients(&mut group, gid, &addr)
            }));
        }
    }
    let mut plain_handles = Vec::new();
    for id in 0..n {
        if muxed[id] {
            continue;
        }
        let addr = addr.to_string();
        let mode = if pp {
            ClientMode::PP(pp_by_id[id].take().unwrap())
        } else {
            ClientMode::FedNL(fednl_by_id[id].take().unwrap())
        };
        plain_handles.push(std::thread::spawn(move || {
            run_client(&addr, id, mode)
        }));
    }
    (mux_handles, plain_handles)
}

#[test]
fn event_pool_mixed_topology_matches_reference_bitwise() {
    // 16 clients — two mux groups of 5 plus 6 plain blocking clients —
    // through one EventPool master: FedNL with warm start (exercises
    // the SHARD_WARM batch and the shared-broadcast write path) must
    // be bit-identical to the in-process sequential reference.
    let ds = dataset(9, 320, 7);
    let d = ds.d;
    const N: usize = 16;
    let opts = Options {
        rounds: 20,
        track_loss: true,
        warm_start: true,
        ..Default::default()
    };

    let mut ref_clients = fednl_clients(&ds, N, "randseqk");
    let t_ref = run_fednl(&mut ref_clients, &opts, vec![0.0; d]);

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let (muxes, plains) = spawn_mixed(
        &ds,
        N,
        "randseqk",
        &addr,
        false,
        &[(0, 0, 5), (1, 5, 10)],
    );
    let mut pool = EventPool::accept(bound, N).unwrap();
    assert_eq!(pool.n_clients(), N);
    let t_ev = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "event");
    pool.shutdown();
    for h in muxes {
        h.join().unwrap().unwrap();
    }
    for h in plains {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_ref.records.len(), t_ev.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_ev.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
    assert!(t_ev.last_grad_norm() < 1e-8);

    // FedNL-LS through an all-mux topology: the Armijo backtracking
    // probes ride EVAL_LOSS → SHARD_LOSSES batches.
    let opts_ls =
        Options { rounds: 12, track_loss: true, ..Default::default() };
    let mut flat = SeqPool::new(fednl_clients(&ds, N, "toplek"));
    let t_ref = run_fednl_ls_pool(
        &mut flat,
        &opts_ls,
        &LineSearchParams::default(),
        vec![0.0; d],
        "flat-ls",
    );
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let (muxes, plains) = spawn_mixed(
        &ds,
        N,
        "toplek",
        &addr,
        false,
        &[(0, 0, 8), (1, 8, 16)],
    );
    assert!(plains.is_empty());
    let mut pool = EventPool::accept(bound, N).unwrap();
    let t_ev = run_fednl_ls_pool(
        &mut pool,
        &opts_ls,
        &LineSearchParams::default(),
        vec![0.0; d],
        "event-ls",
    );
    pool.shutdown();
    for h in muxes {
        h.join().unwrap().unwrap();
    }
    assert_eq!(t_ref.records.len(), t_ev.records.len());
    for (a, b) in t_ref.records.iter().zip(&t_ev.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "ls round {}",
            a.round
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
}

#[test]
fn event_pool_fault_plan_bit_identical() {
    // The same FaultPlan (kill+rejoin window over a mux-hosted client,
    // injected stragglers, a one-round drop) under quorum < n yields
    // bit-identical FedNL-PP trajectories on the in-process reference
    // and on the readiness transport. The rejoin-round state resync
    // rides SHARD_PULL into the mux group.
    let ds = dataset(7, 120, 31);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let plan =
        FaultPlan::parse("kill@4:1-11,delay@2:0:20,delay@6:3:20,drop@13:2")
            .unwrap();
    let opts = Options {
        rounds: 25,
        policy: RoundPolicy {
            quorum: Some(1),
            deadline_ms: Some(2000),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };
    let (tau, seed) = (3usize, 77u64);

    let mut seq = FaultPool::new(
        SeqPool::new(pp_clients(&ds, N, "topk", &x0)),
        plan.clone(),
    );
    let t_seq = run_fednl_pp_pool(
        &mut seq,
        &opts,
        tau,
        seed,
        x0.clone(),
        "fault-seq",
    );
    assert!(t_seq.records.iter().any(|r| r.missing > 0));

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let (muxes, plains) =
        spawn_mixed(&ds, N, "topk", &addr, true, &[(0, 0, 3)]);
    let mut pool =
        FaultPool::new(EventPool::accept(bound, N).unwrap(), plan);
    let t_ev =
        run_fednl_pp_pool(&mut pool, &opts, tau, seed, x0, "fault-event");
    pool.into_inner().shutdown();
    for h in muxes {
        h.join().unwrap().unwrap();
    }
    for h in plains {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_seq.records.len(), t_ev.records.len());
    for (a, b) in t_seq.records.iter().zip(&t_ev.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        // PP traces report logical byte counters on every transport,
        // and the mux batches preserve per-client atoms exactly.
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.bytes_down, b.bytes_down);
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
    }
    let first = t_seq.records[0].grad_norm;
    assert!(
        t_seq.last_grad_norm() < first * 1e-2,
        "{} -> {}",
        first,
        t_seq.last_grad_norm()
    );
}

#[test]
fn event_pool_corrupt_plan_defended_bit_identical() {
    // Byzantine corruption + the median defense over the readiness
    // transport with a mixed topology (clients 0–2 behind one mux
    // group, 3–5 plain): corruption is injected master-side after the
    // mux batches are unpacked into per-client atoms, so the
    // trajectory — including the robust fold and its `flagged`
    // accounting — must match the in-process reference bit for bit.
    // One attacker lives inside the mux group and one outside.
    let ds = dataset(8, 180, 43);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let rounds = 18u64;
    let mut plan = FaultPlan::none();
    for r in 2..rounds {
        plan = plan
            .with_corrupt(r, 1, CorruptMode::Scale(100.0))
            .with_corrupt(r, 4, CorruptMode::Scale(100.0));
    }
    let opts = Options {
        rounds,
        warm_start: true,
        defense: Some(fednl::robust::Defense::Median),
        ..Default::default()
    };

    let mut seq = FaultPool::new(
        SeqPool::new(fednl_clients(&ds, N, "topk")),
        plan.clone(),
    );
    let t_seq =
        run_fednl_pool(&mut seq, &opts, x0.clone(), "corrupt-def-seq");

    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let (muxes, plains) =
        spawn_mixed(&ds, N, "topk", &addr, false, &[(0, 0, 3)]);
    let mut pool =
        FaultPool::new(EventPool::accept(bound, N).unwrap(), plan);
    let t_ev =
        run_fednl_pool(&mut pool, &opts, x0, "corrupt-def-event");
    pool.into_inner().shutdown();
    for h in muxes {
        h.join().unwrap().unwrap();
    }
    for h in plains {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_seq.records.len(), t_ev.records.len());
    for (a, b) in t_seq.records.iter().zip(&t_ev.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
        assert_eq!(a.flagged, b.flagged, "round {}", a.round);
    }
    // The median fold flags committed−1 on every round, and the
    // defended run converges despite the two ×100 attackers.
    assert!(t_seq.records.iter().all(|r| r.flagged == (N as u32) - 1));
    let first = t_seq.records[0].grad_norm;
    let last = t_seq.last_grad_norm();
    assert!(
        last.is_finite() && last < first * 1e-2,
        "{first} -> {last}"
    );
}

#[test]
fn event_pool_registers_10k_mux_clients() {
    // Scale: 10 000 multiplexed clients over 4 group sockets through
    // one readiness loop, two real FedNL rounds, full commitment, and
    // idle server-side bookkeeping ≤ 4 KiB per client.
    const N: usize = 10_000;
    const GROUPS: usize = 4;
    let ds = dataset(5, 2 * N, 13);
    let d = ds.d;
    let mut shards = ds.split_even(N).unwrap();
    let bound = Bound::bind("127.0.0.1:0").unwrap();
    let addr = bound.local_addr().unwrap().to_string();
    let per = N / GROUPS;
    let mut handles = Vec::new();
    for gid in 0..GROUPS as u32 {
        let chunk: Vec<fednl::data::ClientShard> =
            shards.drain(0..per).collect();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut group: Vec<ClientState> = chunk
                .into_iter()
                .map(|sh| {
                    let id = sh.client_id;
                    ClientState::new(
                        id,
                        Box::new(LogisticOracle::new(sh, 1e-3)),
                        by_name("topk", d, 8, 100 + id as u64).unwrap(),
                        None,
                    )
                })
                .collect();
            run_mux_clients(&mut group, gid, &addr)
        }));
    }
    let mut pool = EventPool::accept(bound, N).unwrap();
    assert_eq!(pool.n_clients(), N);
    assert!(pool.dead_clients().is_empty());
    let opts = Options { rounds: 2, ..Default::default() };
    let t = run_fednl_pool(&mut pool, &opts, vec![0.0; d], "event-10k");
    let idle = pool.idle_bytes_per_client();
    pool.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_eq!(t.records.len(), 2);
    for r in &t.records {
        assert_eq!((r.committed, r.missing), (N as u32, 0), "round {}", r.round);
    }
    assert!(t.last_grad_norm().is_finite());
    assert!(idle <= 4096.0, "idle bookkeeping {idle:.1} B/client");
}

#[test]
fn event_leaf_relay_tree_killrelay_heals_bit_identical() {
    // The failover tentpole on the readiness transport: the same
    // depth-3 tree as the blocking-TCP test — master ← parent P
    // (`--parent 2`) ← child relays A, B — but every *leaf* relay
    // serves its clients through an `--event` downward face (the
    // inner node P must stay blocking; `--parent` and `--event` are
    // exclusive). `killrelay@4:0` severs P mid-run, the orphaned
    // clients rotate to `--fallback` and the master adopts them; the
    // healed trajectory must be bit-identical to the flat desugared
    // plan, with losses confined to the kill round.
    use fednl::coordinator::shard;
    use fednl::net::{
        run_client_with, run_relay_on, ClientOpts, RelayCfg, RelayPool,
    };

    let ds = dataset(8, 120, 53);
    let d = ds.d;
    const N: usize = 6;
    let x0 = vec![0.0; d];
    let plan = FaultPlan::parse("killrelay@4:0").unwrap();
    let opts = Options {
        rounds: 14,
        policy: RoundPolicy {
            quorum: Some(3),
            deadline_ms: Some(2000),
            on_missing: OnMissing::Drop,
        },
        ..Default::default()
    };

    let mut flat = FaultPool::with_shard_layout(
        SeqPool::new(fednl_clients(&ds, N, "topk")),
        plan.clone(),
        2,
    );
    let t_flat =
        run_fednl_pool(&mut flat, &opts, x0.clone(), "evtree-flat");

    let master = Bound::bind("127.0.0.1:0").unwrap();
    let master_addr = master.local_addr().unwrap().to_string();
    let mut shards_by_id: Vec<Option<fednl::data::ClientShard>> =
        ds.split_even(N).unwrap().into_iter().map(Some).collect();
    let mut relays = Vec::new();
    let mut clients = Vec::new();

    let p_bound = Bound::bind("127.0.0.1:0").unwrap();
    let p_addr = p_bound.local_addr().unwrap().to_string();
    let pcfg = RelayCfg {
        shard_id: 0,
        base: 0,
        count: 3,
        listen: String::new(),
        connect: master_addr.clone(),
        children: Some(2),
        ..Default::default()
    };
    relays.push(std::thread::spawn(move || run_relay_on(p_bound, &pcfg)));

    let mut leaves: Vec<(u32, u32, String)> = Vec::new();
    for (s, &(lo, hi)) in shard::partition(3, 2).iter().enumerate() {
        let leaf_bound = Bound::bind("127.0.0.1:0").unwrap();
        let leaf_addr = leaf_bound.local_addr().unwrap().to_string();
        let rcfg = RelayCfg {
            shard_id: s as u32,
            base: lo,
            count: (hi - lo) as usize,
            listen: String::new(),
            connect: p_addr.clone(),
            event: true,
            ..Default::default()
        };
        relays.push(std::thread::spawn(move || {
            run_relay_on(leaf_bound, &rcfg)
        }));
        leaves.push((lo, hi, leaf_addr));
    }
    let c_bound = Bound::bind("127.0.0.1:0").unwrap();
    let c_addr = c_bound.local_addr().unwrap().to_string();
    let ccfg = RelayCfg {
        shard_id: 1,
        base: 3,
        count: 3,
        listen: String::new(),
        connect: master_addr.clone(),
        event: true,
        ..Default::default()
    };
    relays.push(std::thread::spawn(move || run_relay_on(c_bound, &ccfg)));
    leaves.push((3, 6, c_addr));

    for (lo, hi, leaf_addr) in leaves {
        for ci in lo..hi {
            let sh = shards_by_id[ci as usize].take().unwrap();
            let addr = leaf_addr.clone();
            let fallback = master_addr.clone();
            let comp = by_name("topk", d, 8, 100 + ci as u64).unwrap();
            clients.push(std::thread::spawn(move || {
                let id = sh.client_id;
                let oracle = Box::new(LogisticOracle::new(sh, 1e-3));
                run_client_with(
                    &addr,
                    id,
                    ClientMode::FedNL(ClientState::new(
                        id, oracle, comp, None,
                    )),
                    ClientOpts {
                        fallback: vec![fallback],
                        ..Default::default()
                    },
                )
            }));
        }
    }
    let mut pool =
        FaultPool::new(RelayPool::accept(master, 2).unwrap(), plan);
    let t_tree = run_fednl_pool(&mut pool, &opts, x0, "evtree-kill");
    pool.into_inner().shutdown();
    for h in relays {
        h.join().unwrap().unwrap();
    }
    for h in clients {
        h.join().unwrap().unwrap();
    }

    assert_eq!(t_flat.records.len(), t_tree.records.len());
    for (a, b) in t_flat.records.iter().zip(&t_tree.records) {
        assert_eq!(
            a.grad_norm.to_bits(),
            b.grad_norm.to_bits(),
            "round {}",
            a.round
        );
        assert_eq!((a.committed, a.missing), (b.committed, b.missing));
    }
    for r in &t_tree.records {
        let expect = if r.round == 4 { (3, 3) } else { (6, 0) };
        assert_eq!((r.committed, r.missing), expect, "round {}", r.round);
    }
    let first = t_tree.records[0].grad_norm;
    assert!(
        t_tree.last_grad_norm() < first * 1e-2,
        "{} -> {}",
        first,
        t_tree.last_grad_norm()
    );
}

//! Coordination layer: how the master reaches its clients.
//!
//! The FedNL drivers (`algorithms::*`) are written against the
//! [`ClientPool`] trait; three transports implement it:
//!
//! * [`SeqPool`] — in-process, sequential (reference semantics / tests);
//! * [`local_sim::ThreadedPool`] — the paper's single-node multi-core
//!   simulator (§5.12): a worker pool sized to the physical cores,
//!   clients statically dispatched, messages processed as available;
//! * `net::server::RemotePool` — the multi-node TCP master (§7).
//!
//! All three produce bit-identical optimization trajectories (messages
//! are aggregated in client order; f64 reduction order is fixed), which
//! the integration tests assert.

pub mod local_sim;

pub use local_sim::ThreadedPool;

use crate::algorithms::{ClientMsg, ClientState};

/// Master-side view of a set of FedNL clients.
pub trait ClientPool {
    fn n_clients(&self) -> usize;
    fn dim(&self) -> usize;

    /// Short implementation name ("seq", "threaded", "remote") for
    /// logs and tests.
    fn kind_name(&self) -> &'static str {
        "pool"
    }

    /// Theoretical α of the clients' compressor class.
    fn default_alpha(&self) -> f64;

    /// Set the Hessian learning rate on every client.
    fn set_alpha(&mut self, alpha: f64);

    /// Execute one FedNL client round on every client; messages are
    /// returned sorted by client id.
    fn round(&mut self, x: &[f64], round: u64, need_loss: bool)
        -> Vec<ClientMsg>;

    /// Average local loss at `x` (line-search probe).
    fn eval_loss(&mut self, x: &[f64]) -> f64;

    /// Average (f(x), ∇f(x)) reduction — the first-order baselines'
    /// round primitive (one d-vector per client per call).
    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);

    /// Warm-start Hᵢ⁰ = ∇²fᵢ(x⁰); returns packed Hᵢ⁰ per client
    /// (client-id order).
    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>>;

    /// Cumulative transport-level bytes (up, down) if the transport
    /// meters them itself; in-process pools return `None` and the driver
    /// keeps the logical count.
    fn transport_bytes(&self) -> Option<(u64, u64)> {
        None
    }
}

/// Sequential in-process pool — the reference implementation.
pub struct SeqPool {
    pub clients: Vec<ClientState>,
}

impl SeqPool {
    pub fn new(clients: Vec<ClientState>) -> Self {
        assert!(!clients.is_empty());
        Self { clients }
    }
}

impl ClientPool for SeqPool {
    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn dim(&self) -> usize {
        self.clients[0].dim()
    }

    fn kind_name(&self) -> &'static str {
        "seq"
    }

    fn default_alpha(&self) -> f64 {
        self.clients[0].alpha
    }

    fn set_alpha(&mut self, alpha: f64) {
        for c in &mut self.clients {
            c.alpha = alpha;
        }
    }

    fn round(
        &mut self,
        x: &[f64],
        round: u64,
        need_loss: bool,
    ) -> Vec<ClientMsg> {
        self.clients.iter_mut().map(|c| c.round(x, round, need_loss)).collect()
    }

    fn eval_loss(&mut self, x: &[f64]) -> f64 {
        let n = self.clients.len() as f64;
        self.clients.iter_mut().map(|c| c.eval_loss(x)).sum::<f64>() / n
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.clients.iter_mut().map(|c| c.warm_start(x)).collect()
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let inv_n = 1.0 / self.clients.len() as f64;
        let mut g = vec![0.0; x.len()];
        let mut loss = 0.0;
        for c in &mut self.clients {
            let (l, gi) = c.eval_loss_grad(x);
            loss += l;
            crate::linalg::vector::axpy(inv_n, &gi, &mut g);
        }
        (loss * inv_n, g)
    }
}

//! Householder QR factorization (paper component `linalg_matrices`:
//! "Dense matrix implementation for BLAS operations, Cholesky, and QR
//! factorization").
//!
//! Used as a robust least-squares / non-SPD fallback and by the test
//! suite as an independent check on the Cholesky solver.

use super::matrix::Mat;
use super::vector;

/// Compact QR: A (m×n, m ≥ n) = Q·R with Q implicit in Householder
/// reflectors.
pub struct Qr {
    /// Reflectors below the diagonal + R on/above it.
    qr: Mat,
    /// Householder βs.
    betas: Vec<f64>,
}

/// Factor A (m ≥ n required).
pub fn qr(a: &Mat) -> Qr {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "qr: need m ≥ n");
    let mut qr = a.clone();
    let mut betas = vec![0.0; n];
    for k in 0..n {
        // Householder vector for column k below row k.
        let mut norm = 0.0;
        for i in k..m {
            norm += qr.get(i, k) * qr.get(i, k);
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            betas[k] = 0.0;
            continue;
        }
        let alpha = if qr.get(k, k) >= 0.0 { -norm } else { norm };
        let v0 = qr.get(k, k) - alpha;
        // v = [v0, a_{k+1,k}, ..., a_{m-1,k}]; β = 2/(vᵀv). Snapshot v
        // before the update loop — column k is rewritten below.
        let v: Vec<f64> = std::iter::once(v0)
            .chain((k + 1..m).map(|i| qr.get(i, k)))
            .collect();
        let vtv: f64 = vector::norm2_sq(&v);
        let beta = if vtv > 0.0 { 2.0 / vtv } else { 0.0 };
        // Apply H = I − βvvᵀ to the trailing columns k+1..n.
        for j in k + 1..n {
            let mut dot = 0.0;
            for (t, &vi) in v.iter().enumerate() {
                dot += vi * qr.get(k + t, j);
            }
            let s = beta * dot;
            for (t, &vi) in v.iter().enumerate() {
                qr.set(k + t, j, qr.get(k + t, j) - s * vi);
            }
        }
        // Column k becomes [α, 0...0]; store the normalized reflector
        // tail (v/v0) below the diagonal instead of the zeros.
        qr.set(k, k, alpha);
        if v0.abs() > 1e-300 {
            for i in k + 1..m {
                qr.set(i, k, v[i - k] / v0);
            }
            betas[k] = beta * v0 * v0;
        } else {
            for i in k + 1..m {
                qr.set(i, k, 0.0);
            }
            betas[k] = 0.0;
        }
    }
    Qr { qr, betas }
}

impl Qr {
    /// Least-squares solve min ‖Ax − b‖₂ via Qᵀb then back-substitution.
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        assert_eq!(b.len(), m);
        let mut y = b.to_vec();
        // y ← Qᵀ y (apply reflectors in order).
        for k in 0..n {
            if self.betas[k] == 0.0 {
                continue;
            }
            let mut dot = y[k];
            for i in k + 1..m {
                dot += self.qr.get(i, k) * y[i];
            }
            let s = self.betas[k] * dot;
            y[k] -= s;
            for i in k + 1..m {
                y[i] -= s * self.qr.get(i, k);
            }
        }
        // Back-substitute R x = y[..n]. Rank deficiency = a diagonal
        // entry negligible relative to the largest.
        let rmax = (0..n)
            .map(|i| self.qr.get(i, i).abs())
            .fold(0.0f64, f64::max);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in i + 1..n {
                s -= self.qr.get(i, j) * x[j];
            }
            let rii = self.qr.get(i, i);
            if rii.abs() <= 1e-12 * rmax.max(1e-300) {
                return None;
            }
            x[i] = s / rii;
        }
        Some(x)
    }

    /// |det(A)| for square A = Π |r_ii|.
    pub fn abs_det(&self) -> f64 {
        let n = self.qr.cols().min(self.qr.rows());
        (0..n).map(|i| self.qr.get(i, i).abs()).product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::rng::{Pcg64, Rng};

    fn randmat(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        Mat::from_vec(m, n, (0..m * n).map(|_| rng.next_gaussian()).collect())
    }

    #[test]
    fn square_solve_matches_residual() {
        for seed in 0..10 {
            let d = 3 + (seed as usize % 10);
            let a = randmat(d, d, seed);
            let mut rng = Pcg64::seed_from_u64(100 + seed);
            let b: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
            let x = qr(&a).solve(&b).unwrap();
            let mut ax = vec![0.0; d];
            a.matvec(&x, &mut ax);
            for i in 0..d {
                assert!((ax[i] - b[i]).abs() < 1e-8, "seed {seed} i {i}");
            }
        }
    }

    #[test]
    fn agrees_with_cholesky_on_spd() {
        let d = 12;
        let g = randmat(d, d, 3);
        let mut a = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..d {
                    s += g.get(k, i) * g.get(k, j);
                }
                a.set(i, j, s);
            }
        }
        a.add_diag(0.5);
        let b: Vec<f64> = (0..d).map(|i| i as f64 - 3.0).collect();
        let x1 = qr(&a).solve(&b).unwrap();
        let x2 = cholesky::solve_spd(&a, 0.0, &b).unwrap();
        for i in 0..d {
            assert!((x1[i] - x2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn least_squares_overdetermined() {
        // Fit y = 2t + 1 from noisy-free samples: exact recovery.
        let m = 20;
        let mut a = Mat::zeros(m, 2);
        let mut b = vec![0.0; m];
        for t in 0..m {
            a.set(t, 0, t as f64);
            a.set(t, 1, 1.0);
            b[t] = 2.0 * t as f64 + 1.0;
        }
        let x = qr(&a).solve(&b).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-10);
        assert!((x[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(qr(&a).solve(&[1.0, 2.0]).is_none());
    }

    #[test]
    fn abs_det_identity() {
        let a = Mat::identity_scaled(5, 3.0);
        let f = qr(&a);
        assert!((f.abs_det() - 243.0).abs() < 1e-9);
    }

    #[test]
    fn residual_orthogonal_to_columns() {
        // LS optimality: Aᵀ(Ax − b) = 0.
        let a = randmat(15, 4, 7);
        let mut rng = Pcg64::seed_from_u64(8);
        let b: Vec<f64> = (0..15).map(|_| rng.next_gaussian()).collect();
        let x = qr(&a).solve(&b).unwrap();
        let mut ax = vec![0.0; 15];
        a.matvec(&x, &mut ax);
        let mut r = vec![0.0; 15];
        vector::sub(&ax, &b, &mut r);
        let mut atr = vec![0.0; 4];
        a.matvec_t(&r, &mut atr);
        for v in atr {
            assert!(v.abs() < 1e-9, "AᵀR = {v}");
        }
    }
}

//! Sharded aggregation: hierarchical masters behind the pool API.
//!
//! A single master folding every client reply caps fan-in: the
//! coordinator bench's `wait_s`/`total_s` split is time the master
//! spends blocked in `drain()` while replies queue behind one consumer.
//! [`ShardedPool`] inserts an aggregation tier: the client set is
//! partitioned into `S` **contiguous global-id ranges**, each owned by
//! one shard aggregator (any inner [`ClientPool`] — `SeqPool` and
//! `ThreadedPool` partitions in-process here, a TCP relay process in
//! `net::relay`), and the top-level master talks to `S` shards instead
//! of `n` clients. FedNL's server update `Hᵏ += (α/n)Σᵢ Sᵢᵏ` is a sum
//! of sums, so the tier changes *where* the folding happens, never the
//! math.
//!
//! # Determinism: true arithmetic pre-reduction
//!
//! The headline invariant of the tier is that **trajectories are
//! bit-identical between unsharded and sharded runs for any S, for
//! FedNL / FedNL-LS / FedNL-PP, on every transport**. Since the
//! reproducible summation layer ([`crate::linalg::reduce`]) the
//! invariant holds **by construction**: every round quantity folds
//! into an exact, associative superaccumulator
//! ([`crate::algorithms::RoundSum`]), so a shard can sum its
//! partition's replies **arithmetically** and forward one merged
//! accumulator per round ([`ClientPool::drain_sums`]; `SHARD_SUM` on
//! the TCP relay) — the master merges S partial sums and obtains
//! bit-for-bit the state the flat fold of all n atoms produces.
//! Master fan-in payload and fold work drop from O(n·d) to O(S·d).
//!
//! * FedNL / FedNL-LS rounds ride the sum path (full-participation
//!   rounds are exactly where O(n·d) fan-in bites);
//! * FedNL-PP rounds keep per-client atoms on the wire — the engine's
//!   rejoin-resync mirrors need per-client deltas, and a τ-subset
//!   round is already sublinear — while the master-side folds still
//!   run through the same exact accumulator;
//! * the probe reductions (`eval_loss`, `loss_grad`, `warm_start`,
//!   `init_state`) concatenate per-client entries across shards and
//!   fold them through the provided reproducible [`ClientPool`]
//!   reductions — grouping-invariant, so no ordering discipline is
//!   needed anywhere.
//!
//! # Fault tolerance through the tier
//!
//! The PR 3 machinery composes: a shard certifies its partition's lost
//! clients upward through [`ClientPool::take_missing`], and a lost
//! shard (TCP relay gone) certifies its **whole partition**, which the
//! engine's quorum/`on_missing` policy then absorbs like any other
//! loss. A master-side [`super::FaultPool`] wraps a `ShardedPool`
//! unchanged, so the same `FaultPlan` yields bit-identical lossy
//! trajectories sharded or not (asserted by the integration tests).
//!
//! [`CommitBuffer`]: crate::algorithms::engine

use std::time::{Duration, Instant};

use super::{ClientFamily, ClientPool, PoolClient, SeqPool, ThreadedPool};
use crate::algorithms::{ClientMsg, RoundSum};
use crate::linalg::reduce::{RepAcc, RepVec};

/// Per-shard accounting of one run: how long the master was blocked
/// draining this shard, how long it spent committing this shard's
/// batches, and how many messages the shard forwarded. The shard bench
/// serializes these into `BENCH_shard.json`.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Clients of this shard's partition.
    pub clients: usize,
    /// Seconds the master spent blocked in this shard's `drain`.
    pub wait_s: f64,
    /// Seconds the master spent committing batches this shard served
    /// (measured as the gap between serving a batch and the next
    /// `drain` call).
    pub aggregate_s: f64,
    /// Round messages folded by this shard.
    pub msgs: u64,
    /// Logical shard→master payload bytes (the `SHARD_SUM` frames this
    /// shard's pre-reduced rounds produced — what the TCP relay tier
    /// would meter on the upward link). O(d) per round, independent of
    /// the partition's client count.
    pub payload_bytes: u64,
}

/// Contiguous balanced partition of `n` clients into `s` shards:
/// shard `i` owns global ids `[i·n/s, (i+1)·n/s)`.
pub fn partition(n: usize, s: usize) -> Vec<(u32, u32)> {
    assert!(s >= 1 && s <= n, "need 1 <= shards ({s}) <= clients ({n})");
    (0..s)
        .map(|i| ((i * n / s) as u32, ((i + 1) * n / s) as u32))
        .collect()
}

/// The in-process sharded aggregation tier (see the module docs). The
/// TCP sibling — real relay processes — is `net::relay::RelayPool`;
/// both present the same [`ClientPool`] face to the round engine.
pub struct ShardedPool {
    shards: Vec<Box<dyn ClientPool>>,
    /// Global-id range `[lo, hi)` of each shard, ascending, contiguous
    /// from the pool's base (0 for a top-level tier; an inner tier of
    /// a deeper tree serves its own contiguous sub-partition).
    ranges: Vec<(u32, u32)>,
    n_clients: usize,
    /// Per-shard "this round is fully drained" flags.
    closed: Vec<bool>,
    stats: Vec<ShardStats>,
    /// (shard whose batch the caller is committing, when it was
    /// served) — attributes the master's commit time per shard.
    serving: Option<(usize, Instant)>,
}

impl ShardedPool {
    /// Build the tier over pre-constructed shard aggregators. Each
    /// `shards[i]` must own exactly the clients of `ranges[i]` and the
    /// ranges must tile a contiguous ascending global-id interval
    /// (starting at 0 for a top-level tier; an inner tier of a deeper
    /// tree tiles its own `[base, base+m)` sub-partition — a
    /// `ShardedPool` is itself a [`ClientPool`], so tiers nest into
    /// S-ary trees of any depth and the exact pre-reduction composes).
    /// The shards must agree on dimension and client family.
    pub fn from_shards(
        shards: Vec<Box<dyn ClientPool>>,
        ranges: Vec<(u32, u32)>,
    ) -> Self {
        assert!(!shards.is_empty(), "need at least one shard");
        assert_eq!(shards.len(), ranges.len());
        let base = ranges[0].0;
        let mut expect = base;
        for (s, &(lo, hi)) in ranges.iter().enumerate() {
            assert!(
                lo == expect && hi > lo,
                "shard {s}: range [{lo}, {hi}) must continue at {expect}"
            );
            assert_eq!(
                shards[s].n_clients(),
                (hi - lo) as usize,
                "shard {s}: pool size vs range mismatch"
            );
            expect = hi;
        }
        let d = shards[0].dim();
        let family = shards[0].family();
        for (s, sh) in shards.iter().enumerate() {
            assert_eq!(sh.dim(), d, "shard {s}: dimension mismatch");
            assert_eq!(
                sh.family(),
                family,
                "shard {s}: shards are family-homogeneous"
            );
        }
        let n_clients = (expect - base) as usize;
        let n_shards = shards.len();
        let stats = ranges
            .iter()
            .enumerate()
            .map(|(shard, &(lo, hi))| ShardStats {
                shard,
                clients: (hi - lo) as usize,
                wait_s: 0.0,
                aggregate_s: 0.0,
                msgs: 0,
                payload_bytes: 0,
            })
            .collect();
        Self {
            shards,
            ranges,
            n_clients,
            closed: vec![true; n_shards],
            stats,
            serving: None,
        }
    }

    /// Partition `clients` (ascending ids `0..n`) into `n_shards`
    /// sequential shard aggregators.
    pub fn new_seq<C: PoolClient + 'static>(
        clients: Vec<C>,
        n_shards: usize,
    ) -> Self {
        Self::build(clients, n_shards, |part| {
            Box::new(SeqPool::new(part))
        })
    }

    /// Partition `clients` into `n_shards` multi-threaded shard
    /// aggregators (`workers` threads each; 0 = auto).
    pub fn new_threaded<C: PoolClient + 'static>(
        clients: Vec<C>,
        n_shards: usize,
        workers: usize,
    ) -> Self {
        Self::build(clients, n_shards, |part| {
            Box::new(ThreadedPool::new(part, workers))
        })
    }

    fn build<C: PoolClient + 'static>(
        clients: Vec<C>,
        n_shards: usize,
        make: impl Fn(Vec<C>) -> Box<dyn ClientPool>,
    ) -> Self {
        for (i, c) in clients.iter().enumerate() {
            assert_eq!(
                c.id(),
                i,
                "sharded partitions need ascending client ids 0..n"
            );
        }
        let ranges = partition(clients.len(), n_shards);
        let mut rest = clients;
        let mut shards: Vec<Box<dyn ClientPool>> = Vec::new();
        for &(lo, hi) in &ranges {
            let tail = rest.split_off((hi - lo) as usize);
            shards.push(make(std::mem::replace(&mut rest, tail)));
        }
        Self::from_shards(shards, ranges)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns global client id `client`.
    pub fn shard_of(&self, client: u32) -> usize {
        self.ranges
            .iter()
            .position(|&(lo, hi)| client >= lo && client < hi)
            .unwrap_or_else(|| panic!("client {client} outside every shard"))
    }

    /// Per-shard wait/aggregate accounting accumulated so far.
    pub fn shard_stats(&self) -> &[ShardStats] {
        &self.stats
    }

    /// Close the commit-time attribution window of the last served
    /// batch (called on every `drain` entry).
    fn settle_serving(&mut self) {
        if let Some((s, since)) = self.serving.take() {
            self.stats[s].aggregate_s += since.elapsed().as_secs_f64();
        }
    }
}

impl ClientPool for ShardedPool {
    fn n_clients(&self) -> usize {
        self.n_clients
    }

    fn dim(&self) -> usize {
        self.shards[0].dim()
    }

    fn family(&self) -> ClientFamily {
        self.shards[0].family()
    }

    fn kind_name(&self) -> &'static str {
        "sharded"
    }

    fn default_alpha(&self) -> f64 {
        self.shards[0].default_alpha()
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        let mut effective = alpha;
        for sh in &mut self.shards {
            effective = sh.set_alpha(alpha);
        }
        effective
    }

    fn prepare_round(&mut self, round: u64) {
        for sh in &mut self.shards {
            sh.prepare_round(round);
        }
    }

    fn set_reply_deadline(&mut self, deadline: Option<Duration>) {
        for sh in &mut self.shards {
            sh.set_reply_deadline(deadline);
        }
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert!(
            self.closed.iter().all(|c| *c),
            "previous round not fully drained"
        );
        self.serving = None;
        for s in 0..self.shards.len() {
            let (lo, hi) = self.ranges[s];
            match subset {
                None => {
                    self.shards[s].submit_round(x, None, round, need_loss);
                    self.closed[s] = false;
                }
                Some(sub) => {
                    // The partition's participants, in subset order —
                    // the order this shard commits in.
                    let part: Vec<u32> = sub
                        .iter()
                        .copied()
                        .filter(|&c| c >= lo && c < hi)
                        .collect();
                    if part.is_empty() {
                        self.closed[s] = true;
                    } else {
                        self.shards[s].submit_round(
                            x,
                            Some(&part),
                            round,
                            need_loss,
                        );
                        self.closed[s] = false;
                    }
                }
            }
        }
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        self.settle_serving();
        // Ascending shard id: the master folds shard batches in shard
        // order; the engine's CommitBuffer restores global subset
        // order, so this only determines *overlap*, never the result.
        for s in 0..self.shards.len() {
            if self.closed[s] {
                continue;
            }
            let since = Instant::now();
            let batch = self.shards[s].drain();
            self.stats[s].wait_s += since.elapsed().as_secs_f64();
            if batch.is_empty() {
                self.closed[s] = true;
                continue;
            }
            self.stats[s].msgs += batch.len() as u64;
            self.serving = Some((s, Instant::now()));
            return batch;
        }
        Vec::new()
    }

    fn drain_sums(&mut self) -> Vec<RoundSum> {
        // The sum path: each shard's partition is pumped to closure
        // and folded into **one** merged accumulator — exactly what a
        // TCP relay ships as its SHARD_SUM frame. Ascending shard id,
        // one shard per call; exactness makes the grouping invisible
        // to the engine. The shard's missing-certificates surface
        // through `take_missing` as on the atom path.
        self.settle_serving();
        for s in 0..self.shards.len() {
            if self.closed[s] {
                continue;
            }
            let since = Instant::now();
            let mut acc = RoundSum::new();
            loop {
                let batch = self.shards[s].drain_sums();
                if batch.is_empty() {
                    break;
                }
                for sum in batch {
                    acc.merge(sum);
                }
            }
            self.closed[s] = true;
            self.stats[s].wait_s += since.elapsed().as_secs_f64();
            if acc.committed == 0 {
                continue; // whole partition certified missing
            }
            self.stats[s].msgs += acc.committed as u64;
            // Logical SHARD_SUM frame size (header + shard id + sum
            // payload + empty missing list) — the byte accounting the
            // TCP relay tier meters for real.
            let bytes = crate::net::FRAME_HEADER_BYTES
                + 4
                + acc.encoded_bytes()
                + 4;
            acc.wire_bytes = bytes;
            self.stats[s].payload_bytes += bytes;
            self.serving = Some((s, Instant::now()));
            return vec![acc];
        }
        Vec::new()
    }

    fn take_missing(&mut self) -> Vec<u32> {
        let mut out = Vec::new();
        for sh in &mut self.shards {
            out.extend(sh.take_missing());
        }
        out
    }

    fn dead_clients(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for sh in &self.shards {
            out.extend(sh.dead_clients());
        }
        out.sort_unstable();
        out
    }

    fn take_rejoined(&mut self) -> Vec<u32> {
        let mut out = Vec::new();
        for sh in &mut self.shards {
            out.extend(sh.take_rejoined());
        }
        out.sort_unstable();
        out
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        let mut out = Vec::with_capacity(self.n_clients);
        for sh in &mut self.shards {
            out.extend(sh.eval_loss_each(x));
        }
        out
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        let mut out = Vec::with_capacity(self.n_clients);
        for sh in &mut self.shards {
            out.extend(sh.loss_grad_each(x));
        }
        out
    }

    fn loss_grad_sum(&mut self, x: &[f64]) -> (RepAcc, RepVec, u32) {
        // Pre-reduced probe: each shard folds its partition next to
        // the clients and hands back one (Σf, Σ∇f) accumulator pair
        // (`SHARD_GRAD_SUM` on the TCP relay tier); merging them is
        // exact, so the result is bit-identical to the flat fold.
        let mut loss = RepAcc::new();
        let mut gsum = RepVec::new(x.len());
        let mut count = 0u32;
        for sh in &mut self.shards {
            let (l, g, c) = sh.loss_grad_sum(x);
            loss.merge(l);
            gsum.merge(g);
            count += c;
        }
        (loss, gsum, count)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        // Shards return partition order (ascending global id);
        // ascending shard id concatenation keeps the global order.
        let mut out = Vec::with_capacity(self.n_clients);
        for sh in &mut self.shards {
            out.extend(sh.warm_start(x));
        }
        out
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        let mut out = Vec::with_capacity(self.n_clients);
        for sh in &mut self.shards {
            out.extend(sh.init_state());
        }
        out
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        let s = self.shard_of(client);
        self.shards[s].pull_state(client)
    }

    fn take_fresh_rejoined(&mut self) -> Vec<u32> {
        let mut out = Vec::new();
        for sh in &mut self.shards {
            out.extend(sh.take_fresh_rejoined());
        }
        out.sort_unstable();
        out
    }

    fn ack_round(&mut self, round: u64, committed: &[u32]) {
        for s in 0..self.shards.len() {
            let (lo, hi) = self.ranges[s];
            let part: Vec<u32> = committed
                .iter()
                .copied()
                .filter(|&c| c >= lo && c < hi)
                .collect();
            if !part.is_empty() {
                self.shards[s].ack_round(round, &part);
            }
        }
    }

    fn resolve_staged(&mut self, client: u32, last_commit: Option<u64>) {
        let s = self.shard_of(client);
        self.shards[s].resolve_staged(client, last_commit);
    }

    fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
        // Exact only if every shard can serve its partition (ascending
        // shard order keeps global client-id order).
        let mut out = Vec::with_capacity(self.n_clients);
        for sh in &mut self.shards {
            out.extend(sh.pull_h_packed()?);
        }
        Some(out)
    }

    fn shard_ranges(&self) -> Option<Vec<(u32, u32)>> {
        Some(self.ranges.clone())
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        // Metered only when every shard meters (the TCP relay tier);
        // in-process partitions keep the drivers' logical accounting.
        let mut up = 0u64;
        let mut down = 0u64;
        for sh in &self.shards {
            let (u, d) = sh.transport_bytes()?;
            up += u;
            down += d;
        }
        Some((up, down))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::ClientState;
    use crate::compressors::by_name;
    use crate::coordinator::SeqPool;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn make_clients(n: usize, seed: u64) -> (Vec<ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 7,
            n_samples: n * 24,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let cs = ds
            .split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name("topk", d, 2, seed + i as u64).unwrap(),
                    None,
                )
            })
            .collect();
        (cs, d)
    }

    #[test]
    fn partition_is_contiguous_and_balanced() {
        assert_eq!(partition(6, 2), vec![(0, 3), (3, 6)]);
        assert_eq!(partition(7, 3), vec![(0, 2), (2, 4), (4, 7)]);
        assert_eq!(partition(5, 1), vec![(0, 5)]);
        assert_eq!(partition(4, 4), vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let p = partition(1000, 7);
        assert_eq!(p[0].0, 0);
        assert_eq!(p.last().unwrap().1, 1000);
        for w in p.windows(2) {
            assert_eq!(w[0].1, w[1].0);
            let (a, b) = (w[0].1 - w[0].0, w[1].1 - w[1].0);
            assert!(a.abs_diff(b) <= 1, "unbalanced: {a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "shards")]
    fn partition_rejects_more_shards_than_clients() {
        let _ = partition(3, 4);
    }

    #[test]
    fn round_and_reductions_cover_all_clients() {
        let (cs, d) = make_clients(6, 41);
        let mut pool = ShardedPool::new_seq(cs, 3);
        assert_eq!(pool.n_clients(), 6);
        assert_eq!(pool.n_shards(), 3);
        assert_eq!(pool.shard_of(0), 0);
        assert_eq!(pool.shard_of(2), 1);
        assert_eq!(pool.shard_of(5), 2);
        let x = vec![0.1; d];
        let msgs = pool.round(&x, 0, true);
        let ids: Vec<usize> = msgs.iter().map(|m| m.client_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        let mut parts = pool.eval_loss_each(&x);
        parts.sort_by_key(|&(id, _)| id);
        let part_ids: Vec<u32> = parts.iter().map(|&(id, _)| id).collect();
        assert_eq!(part_ids, vec![0, 1, 2, 3, 4, 5]);
        // Stats observed a full round through every shard.
        let served: u64 =
            pool.shard_stats().iter().map(|s| s.msgs).sum();
        assert_eq!(served, 6);
    }

    #[test]
    fn subset_round_routes_to_owning_shards_only() {
        let (cs, d) = make_clients(6, 42);
        let mut pool = ShardedPool::new_seq(cs, 2);
        let x = vec![0.05; d];
        // Subset order [5, 0, 1]: shard 1 serves 5, shard 0 serves
        // 0 then 1 (partition-restricted subset order).
        pool.submit_round(&x, Some(&[5, 0, 1]), 0, false);
        let mut got = Vec::new();
        loop {
            let batch = pool.drain();
            if batch.is_empty() {
                break;
            }
            got.extend(batch.into_iter().map(|m| m.client_id as u32));
        }
        assert_eq!(got, vec![0, 1, 5]);
        // Pool reusable: an untouched-shard subset next.
        pool.submit_round(&x, Some(&[4]), 1, false);
        let mut got = Vec::new();
        loop {
            let batch = pool.drain();
            if batch.is_empty() {
                break;
            }
            got.extend(batch.into_iter().map(|m| m.client_id as u32));
        }
        assert_eq!(got, vec![4]);
    }

    #[test]
    fn matches_flat_seq_pool_bitwise_on_probes() {
        let (cs1, d) = make_clients(5, 43);
        let (cs2, _) = make_clients(5, 43);
        let mut flat = SeqPool::new(cs1);
        let mut sharded = ShardedPool::new_seq(cs2, 2);
        let x = vec![0.2; d];
        assert_eq!(flat.eval_loss(&x).to_bits(), sharded.eval_loss(&x).to_bits());
        let (l1, g1) = flat.loss_grad(&x);
        let (l2, g2) = sharded.loss_grad(&x);
        assert_eq!(l1.to_bits(), l2.to_bits());
        for (a, b) in g1.iter().zip(&g2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn drain_sums_matches_atom_fold_and_meters_payload() {
        // The pre-reduced path must produce exactly the sum the atom
        // path produces (exact associativity), with one merged
        // accumulator per shard and O(d) payload accounting.
        let (cs1, d) = make_clients(6, 45);
        let (cs2, _) = make_clients(6, 45);
        let x = vec![0.15; d];
        // Atom reference: flat fold of all six messages.
        let mut flat = SeqPool::new(cs1);
        flat.submit_round(&x, None, 0, true);
        let mut all = Vec::new();
        loop {
            let batch = flat.drain();
            if batch.is_empty() {
                break;
            }
            all.extend(batch);
        }
        let mut want = crate::algorithms::RoundSum::from_msgs(&all);
        // Sharded sum path.
        let mut pool = ShardedPool::new_seq(cs2, 3);
        pool.submit_round(&x, None, 0, true);
        let mut merged = crate::algorithms::RoundSum::new();
        let mut frames = 0;
        loop {
            let sums = pool.drain_sums();
            if sums.is_empty() {
                break;
            }
            for s in sums {
                frames += 1;
                merged.merge(s);
            }
        }
        assert_eq!(frames, 3, "one merged sum per shard");
        assert_eq!(merged.committed, 6);
        assert_eq!(
            merged.l.round().to_bits(),
            want.l.round().to_bits()
        );
        let a: Vec<u64> = merged
            .grad
            .round_vec()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let b: Vec<u64> =
            want.grad.round_vec().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        // Payload metered per shard, and far below the atom bytes for
        // the gradient-dominated part is not guaranteed at this tiny
        // scale — only that it was recorded and is O(d)-shaped.
        for st in pool.shard_stats() {
            assert!(st.payload_bytes > 0, "shard {}", st.shard);
            assert_eq!(st.msgs, 2);
        }
        // Pool is reusable for a next round after the sum path.
        pool.submit_round(&x, None, 1, false);
        let mut n = 0;
        loop {
            let sums = pool.drain_sums();
            if sums.is_empty() {
                break;
            }
            n += sums.iter().map(|s| s.committed).sum::<u32>();
        }
        assert_eq!(n, 6);
    }

    #[test]
    fn nested_shard_tiers_match_flat_bitwise() {
        // A depth-3 tree built from in-process tiers: the outer pool
        // serves [0,6) through shard 0 = SeqPool([0,2)) and shard 1 =
        // an inner ShardedPool serving [2,6) (base 2 > 0) with its own
        // two SeqPool leaves. Pre-reduction must compose exactly: the
        // merged sum and every probe are bit-identical to a flat pool.
        let (cs1, d) = make_clients(6, 46);
        let (cs2, _) = make_clients(6, 46);
        let mut flat = SeqPool::new(cs1);

        let mut it = cs2.into_iter();
        let a: Vec<ClientState> = it.by_ref().take(2).collect();
        let b: Vec<ClientState> = it.by_ref().take(2).collect();
        let c: Vec<ClientState> = it.collect();
        let inner_shards: Vec<Box<dyn ClientPool>> =
            vec![Box::new(SeqPool::new(b)), Box::new(SeqPool::new(c))];
        let inner =
            ShardedPool::from_shards(inner_shards, vec![(2, 4), (4, 6)]);
        assert_eq!(inner.n_clients(), 4);
        let outer_shards: Vec<Box<dyn ClientPool>> =
            vec![Box::new(SeqPool::new(a)), Box::new(inner)];
        let mut tree =
            ShardedPool::from_shards(outer_shards, vec![(0, 2), (2, 6)]);
        assert_eq!(tree.n_clients(), 6);
        assert_eq!(
            tree.shard_ranges(),
            Some(vec![(0, 2), (2, 6)])
        );

        let x = vec![0.12; d];
        assert_eq!(
            flat.eval_loss(&x).to_bits(),
            tree.eval_loss(&x).to_bits()
        );
        // Sum-mode round: the tree pre-reduces per tier; the merge of
        // its (at most two) top-level sums must equal the flat fold.
        flat.submit_round(&x, None, 0, true);
        let mut all = Vec::new();
        loop {
            let batch = flat.drain();
            if batch.is_empty() {
                break;
            }
            all.extend(batch);
        }
        let mut want = crate::algorithms::RoundSum::from_msgs(&all);
        tree.submit_round(&x, None, 0, true);
        let mut got = crate::algorithms::RoundSum::new();
        loop {
            let sums = tree.drain_sums();
            if sums.is_empty() {
                break;
            }
            for s in sums {
                got.merge(s);
            }
        }
        assert_eq!(got.committed, 6);
        assert_eq!(got.l.round().to_bits(), want.l.round().to_bits());
        let a: Vec<u64> =
            got.grad.round_vec().iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> =
            want.grad.round_vec().iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        // Hook routing reaches through the tiers.
        assert!(tree.pull_state(5).is_some());
        tree.resolve_staged(3, None);
        tree.ack_round(0, &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    #[should_panic(expected = "must continue at")]
    fn from_shards_rejects_gapped_ranges() {
        let (cs, _) = make_clients(4, 44);
        let mut it = cs.into_iter();
        let a: Vec<ClientState> = it.by_ref().take(2).collect();
        let b: Vec<ClientState> = it.collect();
        let shards: Vec<Box<dyn ClientPool>> =
            vec![Box::new(SeqPool::new(a)), Box::new(SeqPool::new(b))];
        let _ = ShardedPool::from_shards(shards, vec![(0, 2), (3, 5)]);
    }
}

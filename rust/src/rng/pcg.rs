//! PCG-XSL-RR 128/64 — a small, fast, statistically strong PRNG.
//!
//! Chosen because (a) it is trivially seedable and bit-reproducible
//! across master/client for seed-based index reconstruction (§7), and
//! (b) the state is two u64s, so per-client generators are cheap
//! (paper v62 optimizes "inside pseudo-random generators").

use super::Rng;

const MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR with 128-bit state and 64-bit output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd stream selector
}

impl Pcg64 {
    /// Construct from a full (state, stream) pair.
    pub fn new(seed: u128, stream: u128) -> Self {
        let inc = (stream << 1) | 1;
        let mut pcg = Self { state: 0, inc };
        pcg.state = pcg.state.wrapping_mul(MULT).wrapping_add(inc);
        pcg.state = pcg.state.wrapping_add(seed);
        pcg.state = pcg.state.wrapping_mul(MULT).wrapping_add(inc);
        pcg
    }

    /// Convenience 64-bit seeding (SplitMix-expanded to 128 bits).
    pub fn seed_from_u64(seed: u64) -> Self {
        let a = splitmix64(seed);
        let b = splitmix64(a);
        let c = splitmix64(b);
        let d = splitmix64(c);
        Self::new(
            ((a as u128) << 64) | b as u128,
            ((c as u128) << 64) | d as u128,
        )
    }

    /// Derive a child generator (per-client / per-round streams).
    pub fn derive(&self, tag: u64) -> Self {
        Self::seed_from_u64(splitmix64(
            (self.state >> 64) as u64 ^ self.state as u64 ^ tag,
        ))
    }

    /// Raw (state, inc) pair — the checkpoint codec snapshots the
    /// generator mid-stream so a restored master resumes the *same*
    /// draw sequence, not a reseeded one.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild from a [`state_parts`] snapshot, bit-exact.
    ///
    /// [`state_parts`]: Pcg64::state_parts
    pub fn from_parts(state: u128, inc: u128) -> Self {
        Self { state, inc }
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        // XSL-RR output: xor-shift-low, random rotate.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

/// SplitMix64 — seed expander.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        // The wire protocol depends on bit-identical replay from a seed.
        let mut a = Pcg64::seed_from_u64(123);
        let mut b = Pcg64::seed_from_u64(123);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_is_deterministic_and_distinct() {
        let root = Pcg64::seed_from_u64(9);
        let mut c1 = root.derive(5);
        let mut c2 = root.derive(5);
        let mut c3 = root.derive(6);
        assert_eq!(c1.next_u64(), c2.next_u64());
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn equidistribution_rough() {
        // Chi-square-ish sanity over 16 buckets.
        let mut r = Pcg64::seed_from_u64(42);
        let mut buckets = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[(r.next_u64() >> 60) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        for b in buckets {
            assert!((b as f64 - expect).abs() < expect * 0.05, "bucket {b}");
        }
    }

    #[test]
    fn state_parts_round_trip_mid_stream() {
        // Snapshot after 17 draws; the rebuilt generator must continue
        // the identical sequence (checkpoint/restore leans on this).
        let mut a = Pcg64::seed_from_u64(7);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg64::from_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_values() {
        // First outputs for seed 0 (reference: Vigna's splitmix64.c).
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }
}

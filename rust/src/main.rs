//! `fednl` — leader entrypoint and CLI.
//!
//! Subcommands (paper App. L.5 binaries, unified):
//!   datagen      synthetic LIBSVM dataset generator (bin_opt_problem_generator)
//!   split        split a LIBSVM file into per-client shards (bin_split)
//!   train        single-node multi-core simulation (bin_fednl_local[_pp])
//!   master       multi-node master (bin_fednl_distr_master)
//!   client       multi-node client (bin_fednl_distr_client)
//!   verify       finite-difference oracle verification (numerics)
//!   experiment   regenerate a paper table/figure (see DESIGN.md §4)
//!   sysinfo      host introspection (bin_host_view)

use anyhow::{bail, Context, Result};
use fednl::algorithms::{
    run_engine_from, run_fednl_ls_pool, run_fednl_pool, run_fednl_pp_pool,
    ClientState, LineSearchParams, OnMissing, Options, PPClientState,
    RoundPolicy, StepPolicy, UpdateRule,
};
use fednl::cli::Args;
use fednl::compressors::by_name;
use fednl::coordinator::{
    checkpoint, CheckpointCfg, ClientPool, FaultPlan, FaultPool,
    ShardedPool, Snapshot, ThreadedPool,
};
use fednl::data::{
    generate_synthetic, parse_libsvm_file, write_libsvm, Dataset, SplitSpec,
    SynthSpec,
};
use fednl::harness::{self, HarnessCfg, Scale};
use fednl::metrics::rusage::ResourceSnapshot;
use fednl::metrics::Trace;
use fednl::net::client::ClientMode;
use fednl::net::{
    run_client_with, run_relay, ClientOpts, RelayCfg, RelayPool, RemotePool,
};
use fednl::oracle::{numerics, LogisticOracle, Oracle};
use fednl::runtime::PjrtRuntime;
use fednl::utils::{human_secs, Stopwatch};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("datagen") => cmd_datagen(&args),
        Some("split") => cmd_split(&args),
        Some("train") => cmd_train(&args),
        Some("master") => cmd_master(&args),
        Some("relay") => cmd_relay(&args),
        Some("client") => cmd_client(&args),
        Some("verify") => cmd_verify(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("sysinfo") => cmd_sysinfo(),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "fednl — self-contained compute-optimized FedNL (paper reproduction)\n\n\
         USAGE: fednl <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
         \x20 datagen    --preset w8a|a9a|phishing|quickstart|tiny --out FILE [--seed N]\n\
         \x20            [--label-bias B]\n\
         \x20 split      FILE OUTDIR --clients N [--ni M] [--seed N]\n\
         \x20 train      --data FILE --algo fednl|fednl-ls|fednl-pp [--compressor topk]\n\
         \x20            [--k-mult 8] [--rounds 1000] [--clients 16] [--threads 0]\n\
         \x20            [--lam 1e-3] [--tau 12] [--tol T] [--oracle native|pjrt]\n\
         \x20            [--trace out.csv] [--warm-start] [--rule lk|mu] [--mu 1e-3]\n\
         \x20            [--intra-threads 1] [--quorum Q] [--deadline-ms MS]\n\
         \x20            [--on-missing drop|resample|reuse] [--fault-plan SPEC]\n\
         \x20            [--speculate] [--defense SPEC]\n\
         \x20            [--checkpoint-dir DIR] [--checkpoint-every K]\n\
         \x20            [--split even|power_law:G] [--label-skew P]\n\
         \x20 master     --listen ADDR --clients N --algo ... [--rounds R] [--tol T]\n\
         \x20            [--shards S] [--relay-slack-ms 2000] [--adopt-grace-ms 2000]\n\
         \x20            [--quorum Q] [--deadline-ms MS] [--on-missing P]\n\
         \x20            [--fault-plan SPEC] [--speculate] [--event]\n\
         \x20            [--defense SPEC]\n\
         \x20            [--checkpoint-dir DIR] [--checkpoint-every K]\n\
         \x20            [--restore DIR]\n\
         \x20 relay      --connect MASTER --listen ADDR --shard I --base B --clients K\n\
         \x20            [--event] [--parent S] [--die-after-round R]\n\
         \x20            (shard aggregator: ids [B, B+K) connect here; --parent S\n\
         \x20            serves S child relays instead of clients — S-ary trees)\n\
         \x20 client     --connect ADDR --id I --data SHARD [--algo fednl|fednl-pp]\n\
         \x20            [--compressor topk] [--k-mult 8] [--lam 1e-3] [--mux N]\n\
         \x20            [--fallback A1,A2] [--fresh]\n\
         \x20 verify     --data FILE [--lam 1e-3]   (finite-difference oracle check)\n\
         \x20 experiment table1|table2|table3|table5|fig1..fig12|costmodel|tcpsmoke|\n\
         \x20            faultsmoke|shardsmoke|muxsmoke|failsmoke|corruptsmoke|\n\
         \x20            crashsmoke|all\n\
         \x20            [--full] [--out-dir results] [--pjrt] [--threads N] [--seq]\n\
         \x20            [--label-bias B] [--split SPEC] [--label-skew P]\n\
         \x20 sysinfo\n\n\
         FAULT PLANS (--fault-plan): comma-separated kill@R:C[-R2] | drop@R:C |\n\
         delay@R:C:MS | delaydist@R1-R2:lognormal:MU:SIGMA | killrelay@R:S |\n\
         killmaster@R | corrupt@R:C:MODE with MODE one of\n\
         scale:K | signflip | garbage | zero (Byzantine payload corruption) —\n\
         deterministic master-side injection (see coordinator::faults;\n\
         killrelay needs a master-visible shard S; killmaster needs\n\
         --checkpoint-dir and drops the coordinator's in-memory state at\n\
         round R, rebuilding it from the latest snapshot).\n\
         CHECKPOINTS: --checkpoint-dir DIR --checkpoint-every K write a\n\
         versioned, checksummed snapshot of the full coordinator state\n\
         every K rounds (atomic rename; last 3 kept). `master --restore\n\
         DIR` relaunches from the latest valid snapshot: clients\n\
         reconnect via --fallback, staged rounds above the restored\n\
         watermark are discarded and at-or-below applied (exactly-once),\n\
         and the healed trajectory is bit-identical to an uninterrupted\n\
         run. `experiment crashsmoke` rehearses the full cycle over TCP.\n\
         NON-IID: datagen --label-bias B skews the global label balance;\n\
         --split power_law:G gives Zipf-like client sizes; --label-skew P\n\
         sorts P of each client's quota by label (see data::SplitSpec).\n\
         DEFENSES (--defense): normclip:TAU | median | trimmedmean:F — robust\n\
         server-side aggregation (see the robust module; fednl/fednl-ls only;\n\
         median and trimmed mean route per-client atoms through shard tiers).\n\
         SHARD TIER: `train --shards S` shards in-process; for TCP, run\n\
         `master --shards S`, one `relay` per shard, and point each client at\n\
         its shard's relay. `relay --parent K` nests relays into S-ary trees.\n\
         Trajectories are bit-identical to unsharded runs.\n\
         FAILOVER: `client --fallback` clients stage each round and commit on\n\
         ROUND_ACK; when their relay dies they reconnect up the fallback list\n\
         and the master adopts the orphaned ids — same bits as a flat run.\n\
         EVENT TRANSPORT: `master --event` serves every connection from one\n\
         readiness loop (epoll); `client --mux N` hosts N simulated clients\n\
         of ids [I, I+N) behind one socket — 100k+ clients, one master,\n\
         bit-identical trajectories."
    );
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "quickstart");
    let out = args.get("out").context("--out required")?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let mut spec = SynthSpec::preset(preset)
        .with_context(|| format!("unknown preset '{preset}'"))?;
    spec.seed = seed;
    // Non-IID knob: skew the label balance of the generated problem
    // (0 = balanced; see SynthSpec::label_bias).
    spec.label_bias = args.get_f64("label-bias", 0.0)?;
    let sw = Stopwatch::start();
    let data = generate_synthetic(&spec);
    let text = write_libsvm(&data);
    std::fs::write(out, &text)?;
    println!(
        "wrote {} samples (d_raw={}) to {out} in {}",
        data.labels.len(),
        data.d_raw,
        human_secs(sw.elapsed_secs())
    );
    Ok(())
}

fn cmd_split(args: &Args) -> Result<()> {
    let input = args.positional.first().context("input file required")?;
    let outdir = args.positional.get(1).context("output dir required")?;
    let n = args.get_usize("clients", 4)?;
    let seed = args.get_u64("seed", 1)?;
    let (samples, d_raw) = parse_libsvm_file(input)?;
    let mut ds = Dataset::from_libsvm(&samples, d_raw);
    ds.reshuffle(seed);
    let ni = args.get_usize("ni", ds.n_samples() / n)?;
    std::fs::create_dir_all(outdir)?;
    // Re-emit per-shard LIBSVM files (labels reconstructed from the
    // intercept column sign).
    let shards = ds.split(n, ni)?;
    for sh in &shards {
        let mut text = String::new();
        for r in 0..sh.n_i() {
            let row = sh.at.row(r);
            let label = if row[ds.d - 1] > 0.0 { 1.0 } else { -1.0 };
            text.push_str(if label > 0.0 { "+1" } else { "-1" });
            for (j, &v) in row.iter().enumerate().take(ds.d - 1) {
                if v != 0.0 {
                    text.push_str(&format!(" {}:{}", j + 1, v * label));
                }
            }
            text.push('\n');
        }
        std::fs::write(
            format!("{outdir}/shard_{:04}.libsvm", sh.client_id),
            text,
        )?;
    }
    println!("split {input} into {n} shards of {ni} samples in {outdir}/");
    Ok(())
}

fn load_shards(
    path: &str,
    n_clients: usize,
    seed: u64,
    split: &SplitSpec,
) -> Result<(Dataset, Vec<fednl::data::ClientShard>)> {
    let (samples, d_raw) = parse_libsvm_file(path)?;
    let mut ds = Dataset::from_libsvm(&samples, d_raw);
    ds.reshuffle(seed);
    // `SplitSpec::Even` here reproduces the historical
    // `split_even(n_clients)` byte-for-byte (same n_i derivation).
    let n_i = ds.n_samples() / n_clients;
    let shards = split.shards(&ds, n_clients, n_i, seed)?;
    Ok((ds, shards))
}

fn build_oracle(
    shard: fednl::data::ClientShard,
    lam: f64,
    kind: &str,
    artifacts: &str,
    rt: &mut Option<PjrtRuntime>,
) -> Result<Box<dyn Oracle>> {
    match kind {
        "native" => Ok(Box::new(LogisticOracle::new(shard, lam))),
        "pjrt" => {
            if rt.is_none() {
                *rt = Some(PjrtRuntime::load(artifacts)?);
            }
            Ok(Box::new(rt.as_ref().unwrap().oracle_for_shard(&shard, lam)?))
        }
        other => bail!("unknown oracle kind '{other}'"),
    }
}

/// Shared `--quorum` / `--deadline-ms` / `--on-missing` parsing for
/// `train` and `master`, validated against the run's client count and
/// transport at parse time (`RoundPolicy::validate`): an unsatisfiable
/// policy fails here with a clear message instead of aborting — or
/// hanging — mid-run.
fn round_policy(
    args: &Args,
    n_clients: usize,
    remote: bool,
) -> Result<RoundPolicy> {
    let quorum = match args.get("quorum") {
        None => None,
        Some(v) => Some(v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("--quorum: expected integer, got '{v}'")
        })?),
    };
    let deadline_ms = match args.get("deadline-ms") {
        None => None,
        Some(v) => Some(v.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--deadline-ms: expected integer, got '{v}'")
        })?),
    };
    let on_missing = OnMissing::parse(args.get_or("on-missing", "drop"))?;
    let policy = RoundPolicy { quorum, deadline_ms, on_missing };
    policy.validate(n_clients, remote, args.get("on-missing").is_some())?;
    Ok(policy)
}

/// `--fault-plan SPEC` (empty plan when absent — the `FaultPool`
/// wrapper is transparent then).
fn fault_plan(args: &Args) -> Result<FaultPlan> {
    match args.get("fault-plan") {
        Some(spec) => FaultPlan::parse(spec),
        None => Ok(FaultPlan::none()),
    }
}

/// `--defense SPEC` (`normclip:TAU` | `median` | `trimmedmean:F`),
/// shared by `train` and `master`. Allowlisted to the engines that
/// actually consult `Options.defense` (fednl, fednl-ls): FedNL-PP
/// aggregates *deltas* into persistent state, which a robust fold of
/// one round cannot defend, and any other algo would silently ignore
/// the flag — both rejected here, before data loading.
fn defense_opt(
    args: &Args,
    algo: &str,
) -> Result<Option<fednl::robust::Defense>> {
    match args.get("defense") {
        None => Ok(None),
        Some(spec) => {
            anyhow::ensure!(
                matches!(algo, "fednl" | "fednl-ls"),
                "--defense supports the Newton family (fednl, fednl-ls) \
                 only, not '{algo}'"
            );
            Ok(Some(fednl::robust::Defense::parse(spec)?))
        }
    }
}

/// `--checkpoint-dir DIR [--checkpoint-every K]`, shared by `train`
/// and `master`. A restored master (`master --restore DIR`) keeps
/// extending the same snapshot ladder, so `restore` doubles as the
/// checkpoint directory when `--checkpoint-dir` is absent. A
/// `killmaster@R` rehearsal rebuilds the coordinator from disk, so a
/// plan that schedules one without a checkpoint directory is rejected
/// here, before data loading.
fn checkpoint_cfg(
    args: &Args,
    restore: Option<&str>,
    plan: &FaultPlan,
) -> Result<Option<CheckpointCfg>> {
    match args.get("checkpoint-dir").or(restore) {
        Some(dir) => {
            let every = args.get_u64("checkpoint-every", 1)?;
            anyhow::ensure!(every >= 1, "--checkpoint-every must be >= 1");
            let mut cfg = CheckpointCfg::new(dir, every);
            cfg.plan_spec = args.get_or("fault-plan", "").to_string();
            Ok(Some(cfg))
        }
        None => {
            anyhow::ensure!(
                args.get("checkpoint-every").is_none(),
                "--checkpoint-every needs --checkpoint-dir"
            );
            anyhow::ensure!(
                plan.master_kills.is_empty(),
                "killmaster@R requires --checkpoint-dir: the rebuilt \
                 coordinator restores from the snapshot ladder"
            );
            Ok(None)
        }
    }
}

/// `--split even|power_law:GAMMA` / `--label-skew P` → client
/// partition spec (two spellings of the same knob, so mutually
/// exclusive). Absent both, the paper's IID equal split.
fn split_spec(args: &Args) -> Result<SplitSpec> {
    match (args.get("split"), args.get("label-skew")) {
        (Some(_), Some(_)) => {
            bail!("--split and --label-skew are mutually exclusive")
        }
        (Some(spec), None) => SplitSpec::parse(spec),
        (None, Some(p)) => {
            let p: f64 = p.parse().map_err(|_| {
                anyhow::anyhow!("--label-skew: expected number, got '{p}'")
            })?;
            Ok(SplitSpec::LabelSkew(p))
        }
        (None, None) => Ok(SplitSpec::Even),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let data = args.get("data").context("--data required")?;
    let algo = args.get_or("algo", "fednl");
    let comp = args.get_or("compressor", "topk");
    let k_mult = args.get_usize("k-mult", 8)?;
    let rounds = args.get_u64("rounds", 100)?;
    let n_clients = args.get_usize("clients", 16)?;
    let threads = args.get_usize("threads", 0)?;
    let lam = args.get_f64("lam", 1e-3)?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let oracle_kind = args.get_or("oracle", "native");
    let artifacts = args.get_or("artifacts", "artifacts");
    let tol = args.get("tol").map(|t| t.parse::<f64>()).transpose()?;
    let rule = match args.get_or("rule", "lk") {
        "mu" => UpdateRule::ProjectMu(args.get_f64("mu", 1e-3)?),
        _ => UpdateRule::LkShift,
    };
    // §5.10 intra-client Hessian-accumulate threading (bit-identical
    // at any setting; useful for few-client or --threads 1 runs).
    fednl::linalg::simd::set_intra_threads(
        args.get_usize("intra-threads", 1)?,
    );
    // In-process sharded aggregation tier: S > 1 partitions the
    // clients over S shard aggregators (bit-identical trajectories).
    let n_shards = args.get_usize("shards", 1)?;
    anyhow::ensure!(
        n_shards >= 1 && n_shards <= n_clients,
        "--shards must be in [1, {n_clients}]"
    );
    let sw = Stopwatch::start();
    let (ds, shards) = load_shards(data, n_clients, seed, &split_spec(args)?)?;
    let d = ds.d;
    let init = sw.elapsed_secs();
    let plan = fault_plan(args)?;
    let opts = Options {
        rounds,
        rule,
        tol_grad: tol,
        track_loss: true,
        warm_start: args.flag("warm-start"),
        policy: round_policy(args, n_clients, false)?,
        speculate: args.flag("speculate"),
        defense: defense_opt(args, algo)?,
        checkpoint: checkpoint_cfg(args, None, &plan)?,
        ..Default::default()
    };
    let x0 = vec![0.0; d];
    let mut rt: Option<PjrtRuntime> = None;

    let trace = match algo {
        "fednl" | "fednl-ls" => {
            let clients: Vec<ClientState> = shards
                .into_iter()
                .enumerate()
                .map(|(i, sh)| -> Result<ClientState> {
                    Ok(ClientState::new(
                        i,
                        build_oracle(sh, lam, oracle_kind, artifacts, &mut rt)?,
                        by_name(comp, d, k_mult, seed + i as u64)?,
                        None,
                    ))
                })
                .collect::<Result<_>>()?;
            let mut run = |pool: &mut dyn ClientPool| {
                if algo == "fednl" {
                    run_fednl_pool(
                        pool,
                        &opts,
                        x0.clone(),
                        &format!("FedNL/{comp}"),
                    )
                } else {
                    run_fednl_ls_pool(
                        pool,
                        &opts,
                        &LineSearchParams::default(),
                        x0.clone(),
                        &format!("FedNL-LS/{comp}"),
                    )
                }
            };
            if n_shards > 1 {
                let mut pool = FaultPool::new(
                    ShardedPool::new_threaded(clients, n_shards, threads),
                    plan,
                );
                run(&mut pool)
            } else {
                let mut pool =
                    FaultPool::new(ThreadedPool::new(clients, threads), plan);
                run(&mut pool)
            }
        }
        "fednl-pp" => {
            let tau = args.get_usize("tau", (n_clients / 4).max(1))?;
            let clients: Vec<PPClientState> = shards
                .into_iter()
                .enumerate()
                .map(|(i, sh)| -> Result<PPClientState> {
                    Ok(PPClientState::new(
                        i,
                        build_oracle(sh, lam, oracle_kind, artifacts, &mut rt)?,
                        by_name(comp, d, k_mult, seed + i as u64)?,
                        None,
                        &x0,
                    ))
                })
                .collect::<Result<_>>()?;
            // PP runs on the same multi-core pool as FedNL/LS now that
            // participation subsets are part of the pool API.
            let label = format!("FedNL-PP/{comp}");
            if n_shards > 1 {
                let mut pool = FaultPool::new(
                    ShardedPool::new_threaded(clients, n_shards, threads),
                    plan,
                );
                run_fednl_pp_pool(&mut pool, &opts, tau, seed, x0, &label)
            } else {
                let mut pool =
                    FaultPool::new(ThreadedPool::new(clients, threads), plan);
                run_fednl_pp_pool(&mut pool, &opts, tau, seed, x0, &label)
            }
        }
        other => bail!("unknown algo '{other}'"),
    };

    println!(
        "{}: {} rounds, init {}, solve {}, ||grad|| = {:.3e}, up {}",
        trace.label,
        trace.records.len(),
        human_secs(init),
        human_secs(trace.total_elapsed()),
        trace.last_grad_norm(),
        fednl::utils::human_bytes(trace.total_bytes_up()),
    );
    if trace.overlap_secs > 0.0 {
        println!(
            "speculation overlapped {} of server work with straggler wait",
            human_secs(trace.overlap_secs)
        );
    }
    if let Some(path) = args.get("trace") {
        trace.write_csv(path)?;
        println!("trace written to {path}");
    }
    Ok(())
}

/// Algorithm dispatch shared by the flat and sharded TCP masters.
/// `resume` (from `--restore DIR`) re-enters the engine mid-trajectory;
/// `None` is exactly the historical fresh-start dispatch.
fn run_master_algo(
    pool: &mut dyn ClientPool,
    args: &Args,
    opts: &Options,
    algo: &str,
    n_clients: usize,
    seed: u64,
    resume: Option<Snapshot>,
) -> Result<Trace> {
    let x0 = vec![0.0; pool.dim()];
    let ls = LineSearchParams::default();
    Ok(match algo {
        "fednl" => run_engine_from(
            pool,
            opts,
            StepPolicy::Newton,
            x0,
            "FedNL/tcp",
            resume,
        ),
        "fednl-ls" => run_engine_from(
            pool,
            opts,
            StepPolicy::LineSearch(&ls),
            x0,
            "FedNL-LS/tcp",
            resume,
        ),
        "fednl-pp" => {
            let tau = args.get_usize("tau", (n_clients / 4).max(1))?;
            run_engine_from(
                pool,
                opts,
                StepPolicy::PartialParticipation { tau, seed },
                x0,
                "FedNL-PP/tcp",
                resume,
            )
        }
        other => bail!("unknown algo '{other}'"),
    })
}

fn cmd_master(args: &Args) -> Result<()> {
    let listen = args.get_or("listen", "0.0.0.0:7700");
    let n_clients = args.get_usize("clients", 2)?;
    let n_shards = args.get_usize("shards", 0)?;
    let algo = args.get_or("algo", "fednl");
    let rounds = args.get_u64("rounds", 100)?;
    let tol = args.get("tol").map(|t| t.parse::<f64>()).transpose()?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let plan = fault_plan(args)?;
    // `--restore DIR`: crash recovery. Load the latest valid snapshot
    // (corrupt tails are skipped by `load_latest`) and re-enter the
    // engine at its `round_next`; clients reconnect through their
    // `--fallback` rotation and the staged-commit RESYNC protocol
    // replays exactly-once. Restore is wired for the flat blocking
    // master only: the relay tier and the event transport have their
    // own failover stories (PR 7/8), and a PP master would also need
    // the clients' persistent state to survive, which TCP clients
    // rebuild fresh.
    let restore_dir = args.get("restore");
    let snap: Option<Snapshot> = match restore_dir {
        Some(dir) => {
            anyhow::ensure!(
                n_shards == 0 && !args.flag("event"),
                "--restore supports the flat blocking master only \
                 (no --shards / --event)"
            );
            anyhow::ensure!(
                algo != "fednl-pp",
                "--restore over TCP supports the Newton family only: \
                 reconnecting fednl-pp clients rebuild their persistent \
                 state from scratch, which the snapshot cannot heal"
            );
            let s = checkpoint::load_latest(dir)?.with_context(|| {
                format!("--restore {dir}: no valid snapshot found")
            })?;
            anyhow::ensure!(
                s.n == n_clients,
                "--restore: snapshot has n = {}, --clients says {n_clients}",
                s.n
            );
            println!(
                "master: restoring from {dir} (round {}, {})",
                s.round_next,
                if s.finished { "finished" } else { "in flight" }
            );
            Some(s)
        }
        None => None,
    };
    let opts = Options {
        rounds,
        tol_grad: tol,
        track_loss: algo == "fednl-ls",
        policy: round_policy(args, n_clients, true)?,
        speculate: args.flag("speculate"),
        defense: defense_opt(args, algo)?,
        checkpoint: checkpoint_cfg(args, restore_dir, &plan)?,
        ..Default::default()
    };
    // Relay forwarding slack (`deadline + slack` is how long the
    // master waits for a relay's round frame before certifying the
    // whole partition lost). Validated at parse time like the round
    // policy: an explicit 0 can only be a mistake.
    let relay_slack = fednl::net::relay::relay_slack_from_ms(
        args.get_u64(
            "relay-slack-ms",
            fednl::net::relay::DEFAULT_RELAY_SLACK.as_millis() as u64,
        )?,
    )?;
    anyhow::ensure!(
        args.get("relay-slack-ms").is_none() || n_shards > 0,
        "--relay-slack-ms only applies to a sharded master (--shards S)"
    );
    // Adoption grace: how long the master's rejoin barrier waits for a
    // severed partition's clients to fail over before abandoning the
    // ids (`RelayPool::adopt_orphans`).
    anyhow::ensure!(
        args.get("adopt-grace-ms").is_none() || n_shards > 0,
        "--adopt-grace-ms only applies to a sharded master (--shards S)"
    );
    let trace = if n_shards > 0 {
        // Sharded aggregation tier: S relay aggregators register, each
        // owning a contiguous client partition (`fednl relay`).
        println!("master: waiting for {n_shards} relays on {listen} ...");
        let mut pool =
            FaultPool::new(RelayPool::listen(listen, n_shards)?, plan);
        pool.inner_mut().set_relay_slack(relay_slack);
        if let Some(ms) = args.get("adopt-grace-ms") {
            let ms: u64 = ms
                .parse()
                .context("--adopt-grace-ms: expected milliseconds")?;
            pool.inner_mut().set_adopt_grace(
                fednl::net::relay::adopt_grace_from_ms(ms)?,
            );
        }
        anyhow::ensure!(
            pool.inner_mut().n_clients() == n_clients,
            "relays cover {} clients, --clients says {n_clients}",
            pool.inner_mut().n_clients()
        );
        println!(
            "master: all relays registered (d = {}, n = {n_clients})",
            pool.dim()
        );
        let trace = run_master_algo(
            &mut pool, args, &opts, algo, n_clients, seed, None,
        )?;
        pool.into_inner().shutdown();
        trace
    } else if args.flag("event") {
        // Readiness transport: every socket (plain clients and
        // `--mux` groups alike) served from one epoll loop.
        #[cfg(unix)]
        {
            println!(
                "master: waiting for {n_clients} clients (event transport) \
                 on {listen} ..."
            );
            let bound = fednl::net::server::Bound::bind(listen)?;
            let mut pool = FaultPool::new(
                fednl::net::EventPool::accept(bound, n_clients)?,
                plan,
            );
            println!("master: all clients registered (d = {})", pool.dim());
            let trace = run_master_algo(
                &mut pool, args, &opts, algo, n_clients, seed, None,
            )?;
            pool.into_inner().shutdown();
            trace
        }
        #[cfg(not(unix))]
        {
            bail!("--event requires a unix host (epoll/poll)");
        }
    } else {
        println!("master: waiting for {n_clients} clients on {listen} ...");
        // A restored master re-binds the address the killed one owned;
        // retry while the dead process's sockets drain out of
        // TIME_WAIT (clients hold this address in their --fallback
        // rotation, so it must be the same one).
        let bound = if snap.is_some() {
            fednl::net::server::Bound::bind_retry(listen, 100)?
        } else {
            fednl::net::server::Bound::bind(listen)?
        };
        let mut pool = FaultPool::new(bound.accept(n_clients)?, plan);
        println!("master: all clients registered (d = {})", pool.dim());
        if let Some(s) = &snap {
            // Every client that registered with a restored master is a
            // reconnection: mark them all rejoined so the engine's
            // first prepare resolves their staged commit ladders via
            // RESYNC against the restored watermarks, and advance the
            // fault plan's liveness cursor past the rounds already
            // replayed from the snapshot.
            pool.inner_mut().mark_all_rejoined();
            pool.prime_liveness(s.round_next);
        }
        let trace = run_master_algo(
            &mut pool, args, &opts, algo, n_clients, seed, snap,
        )?;
        pool.into_inner().shutdown();
        trace
    };
    println!(
        "done: {} rounds, ||grad|| = {:.3e}, wall {}",
        trace.records.len(),
        trace.last_grad_norm(),
        human_secs(trace.total_elapsed())
    );
    if let Some(path) = args.get("trace") {
        trace.write_csv(path)?;
    }
    Ok(())
}

fn cmd_relay(args: &Args) -> Result<()> {
    let cfg = RelayCfg {
        shard_id: args.get_usize("shard", 0)? as u32,
        base: args.get_usize("base", 0)? as u32,
        count: args.get_usize("clients", 2)?,
        listen: args.get_or("listen", "0.0.0.0:7800").to_string(),
        connect: args
            .get("connect")
            .context("--connect (master address) required")?
            .to_string(),
        event: args.flag("event"),
        children: match args.get_usize("parent", 0)? {
            0 => None,
            k => Some(k),
        },
        die_after_round: args
            .get("die-after-round")
            .map(|v| v.parse::<u64>())
            .transpose()
            .context("--die-after-round: expected round number")?,
    };
    match cfg.children {
        Some(k) => println!(
            "relay {}: parent of {k} child relays (ids [{}, {})) on {}, \
             master {}",
            cfg.shard_id,
            cfg.base,
            cfg.base as usize + cfg.count,
            cfg.listen,
            cfg.connect
        ),
        None => println!(
            "relay {}: serving clients [{}, {}) on {}, master {}",
            cfg.shard_id,
            cfg.base,
            cfg.base as usize + cfg.count,
            cfg.listen,
            cfg.connect
        ),
    }
    let report = run_relay(&cfg)?;
    println!(
        "relay {}: down {} B in / {} B out, up {} B out / {} B in",
        cfg.shard_id,
        report.down_recv,
        report.down_sent,
        report.up_sent,
        report.up_recv
    );
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("connect").context("--connect required")?;
    let id = args.get_usize("id", 0)?;
    let data = args.get("data").context("--data required")?;
    let comp = args.get_or("compressor", "topk");
    let k_mult = args.get_usize("k-mult", 8)?;
    let lam = args.get_f64("lam", 1e-3)?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let algo = args.get_or("algo", "fednl");
    // Failover: `--fallback a:1,b:2` names the addresses to rotate to
    // (in order) when the current connection dies mid-run; `--fresh`
    // announces restarted-with-reset-state for the exact Hᵢ resync.
    // FedNL-family only — PP clients carry no staged state to commit.
    let fallback = args.get_list("fallback");
    let fresh = args.flag("fresh");
    anyhow::ensure!(
        algo != "fednl-pp" || (fallback.is_empty() && !fresh),
        "--fallback/--fresh run the FedNL commit-ack protocol; \
         fednl-pp clients have no staged state to resync"
    );
    // Interleave dataset parsing with connection establishment (§7).
    let (samples, d_raw) = parse_libsvm_file(data)?;
    let mux = args.get_usize("mux", 0)?;
    if mux > 0 {
        anyhow::ensure!(
            fallback.is_empty() && !fresh,
            "--fallback/--fresh are per-connection client behaviors; \
             a --mux group fails (and is certified) as a unit"
        );
        // Multiplexed mode: host `mux` simulated clients of global ids
        // [id, id+mux) behind ONE socket. The shard file is split
        // evenly — the in-process clients share the parse, the
        // process, and the frame codec, so idle cost per hosted
        // client is their local data plus algorithm state only.
        let mut ds = Dataset::from_libsvm(&samples, d_raw);
        ds.reshuffle(seed);
        let d = ds.d;
        let shards = ds.split_even(mux)?;
        let x0 = vec![0.0; d];
        let report = match algo {
            "fednl-pp" => {
                let mut clients: Vec<PPClientState> = shards
                    .into_iter()
                    .enumerate()
                    .map(|(i, sh)| -> Result<PPClientState> {
                        let gid = id + i;
                        Ok(PPClientState::new(
                            gid,
                            Box::new(LogisticOracle::new(sh, lam)),
                            by_name(comp, d, k_mult, seed + gid as u64)?,
                            None,
                            &x0,
                        ))
                    })
                    .collect::<Result<_>>()?;
                fednl::net::run_mux_clients(&mut clients, id as u32, addr)?
            }
            _ => {
                let mut clients: Vec<ClientState> = shards
                    .into_iter()
                    .enumerate()
                    .map(|(i, sh)| -> Result<ClientState> {
                        let gid = id + i;
                        Ok(ClientState::new(
                            gid,
                            Box::new(LogisticOracle::new(sh, lam)),
                            by_name(comp, d, k_mult, seed + gid as u64)?,
                            None,
                        ))
                    })
                    .collect::<Result<_>>()?;
                fednl::net::run_mux_clients(&mut clients, id as u32, addr)?
            }
        };
        println!(
            "mux group {id} (+{mux}): sent {} B, received {} B",
            report.up_sent, report.up_recv
        );
        return Ok(());
    }
    let ds = Dataset::from_libsvm(&samples, d_raw);
    let d = ds.d;
    let shard = fednl::data::ClientShard { client_id: id, at: ds.at };
    let oracle = Box::new(LogisticOracle::new(shard, lam));
    let compressor = by_name(comp, d, k_mult, seed + id as u64)?;
    let mode = match algo {
        "fednl-pp" => ClientMode::PP(PPClientState::new(
            id,
            oracle,
            compressor,
            None,
            &vec![0.0; d],
        )),
        _ => ClientMode::FedNL(ClientState::new(id, oracle, compressor, None)),
    };
    let opts = ClientOpts { fallback, fresh, ..Default::default() };
    let (sent, recv) = run_client_with(addr, id, mode, opts)?;
    println!("client {id}: sent {sent} B, received {recv} B");
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let data = args.get("data").context("--data required")?;
    let lam = args.get_f64("lam", 1e-3)?;
    let (samples, d_raw) = parse_libsvm_file(data)?;
    let ds = Dataset::from_libsvm(&samples, d_raw);
    let d = ds.d;
    let shard = fednl::data::ClientShard { client_id: 0, at: ds.at };
    let mut oracle = LogisticOracle::new(shard, lam);
    let mut rng = fednl::rng::Pcg64::seed_from_u64(7);
    use fednl::rng::Rng;
    let x: Vec<f64> = (0..d).map(|_| rng.next_gaussian() * 0.2).collect();
    let ge = numerics::check_grad(&mut oracle, &x);
    let he = numerics::check_hessian(&mut oracle, &x);
    println!("gradient FD error: {ge:.3e}\nhessian  FD error: {he:.3e}");
    anyhow::ensure!(ge < 1e-5 && he < 1e-3, "oracle verification FAILED");
    println!("oracle verification OK");
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let cfg = HarnessCfg {
        scale: if args.flag("full") { Scale::Full } else { Scale::Ci },
        out_dir: args.get_or("out-dir", "results").to_string(),
        threads: args.get_usize("threads", 0)?,
        seq: args.flag("seq"),
        pjrt: args.flag("pjrt"),
        artifacts: args.get_or("artifacts", "artifacts").to_string(),
        seed: args.get_u64("seed", 0x5EED)?,
        label_bias: args.get_f64("label-bias", 0.0)?,
        split: split_spec(args)?,
    };
    cfg.ensure_out_dir()?;
    let run = |name: &str| -> Result<String> {
        let sw = Stopwatch::start();
        let body = match name {
            "table1" => harness::table1(&cfg)?,
            "table2" => harness::table2(&cfg)?,
            "table3" => harness::table3(&cfg)?,
            "table5" => harness::table5(&cfg)?,
            "costmodel" => harness::costmodel(),
            "tcpsmoke" => harness::tcp_smoke(&cfg)?,
            "faultsmoke" => harness::fault_smoke(&cfg)?,
            "shardsmoke" => harness::shard_smoke(&cfg)?,
            "muxsmoke" => harness::mux_smoke(&cfg)?,
            "failsmoke" => harness::fail_smoke(&cfg)?,
            "corruptsmoke" => harness::corrupt_smoke(&cfg)?,
            "crashsmoke" => harness::crash_smoke(&cfg)?,
            f if f.starts_with("fig") => {
                let n: usize = f[3..].parse().context("figN")?;
                if n <= 3 {
                    harness::fig_single_node(n, &cfg)?
                } else if n <= 12 {
                    harness::fig_multi_node(n, &cfg)?
                } else {
                    bail!("figures are fig1..fig12")
                }
            }
            other => bail!("unknown experiment '{other}'"),
        };
        Ok(format!(
            "{body}\n_(regenerated in {})_\n",
            human_secs(sw.elapsed_secs())
        ))
    };
    let all = [
        "costmodel", "tcpsmoke", "faultsmoke", "shardsmoke", "muxsmoke",
        "failsmoke", "corruptsmoke", "crashsmoke", "table1", "table2",
        "table3", "table5", "fig1", "fig2", "fig3", "fig4", "fig5", "fig6",
        "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
    ];
    let list: Vec<&str> =
        if which == "all" { all.to_vec() } else { vec![which] };
    let mut report = String::new();
    for name in list {
        eprintln!("[experiment] running {name} ...");
        let body = run(name)?;
        println!("{body}");
        report.push_str(&body);
        report.push('\n');
    }
    let path = format!("{}/report.md", cfg.out_dir);
    std::fs::write(&path, &report)?;
    eprintln!("[experiment] report written to {path}");
    Ok(())
}

fn cmd_sysinfo() -> Result<()> {
    let snap = ResourceSnapshot::capture();
    println!(
        "cores: {}\nopen fds: {}\nVmSize: {} K\nVmPeak: {} K\nVmRSS: {} K\nVmHWM: {} K\nthreads: {}",
        fednl::utils::available_cores(),
        snap.open_fds,
        snap.vm_size_kib,
        snap.vm_peak_kib,
        snap.vm_rss_kib,
        snap.vm_hwm_kib,
        snap.threads
    );
    match PjrtRuntime::load("artifacts") {
        Ok(rt) => {
            println!("artifacts: {} shapes", rt.entries.len());
            for e in &rt.entries {
                println!(
                    "  {} d={} n_i<={} (padded {}x{})",
                    e.name, e.d_raw, e.n_raw, e.d_pad, e.n_pad
                );
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}

//! The unified round engine: one driver loop for the whole FedNL
//! family (Alg. 1–3), over any [`ClientPool`] transport.
//!
//! The engine owns everything the three per-algorithm drivers used to
//! triplicate — α resolution, warm start, the streaming
//! submit/drain/commit loop, byte accounting, trace recording and the
//! tolerance check — and delegates what actually differs to a
//! [`StepPolicy`]:
//!
//! * [`StepPolicy::Newton`] — FedNL (Alg. 1): aggregate, then
//!   xᵏ⁺¹ = xᵏ − [system]⁻¹ ∇f(xᵏ);
//! * [`StepPolicy::LineSearch`] — FedNL-LS (Alg. 2): the same
//!   aggregation, then Armijo backtracking with `eval_loss` probes;
//! * [`StepPolicy::PartialParticipation`] — FedNL-PP (Alg. 3): solve
//!   xᵏ⁺¹ from the persistent (Hᵏ, lᵏ, gᵏ) *before* sampling, then
//!   stream the τ participants' deltas into the persistent state.
//!
//! # Incremental aggregation and the buffer-and-commit rule
//!
//! Replies stream out of [`ClientPool::drain`] in arrival order; the
//! engine hands each to a [`CommitBuffer`], which re-establishes the
//! round's deterministic commit order (subset order; ascending client
//! id for a full round) and applies a message the moment its turn
//! arrives. Early arrivals are buffered, so aggregation work —
//! `Hᵏ += (α/n)·Sᵢᵏ`, gradient partial sums — overlaps with the slower
//! clients' compute and in-flight network transfer, while the
//! resulting f64 reduction stays bit-identical to the blocking
//! sort-then-aggregate it replaces.

use super::fednl_ls::LineSearchParams;
use super::{ClientMsg, Options, ServerState};
use crate::coordinator::{ClientFamily, ClientPool};
use crate::linalg::packed::PackedUpper;
use crate::linalg::{vector, Cholesky, Mat};
use crate::metrics::{RoundRecord, Trace};
use crate::net::wire;
use crate::rng::{sample_distinct, Pcg64};
use crate::utils::Stopwatch;

/// What the master does with an aggregated round (the only part of the
/// driver loop that differs between Alg. 1, 2 and 3).
#[derive(Clone, Copy)]
pub enum StepPolicy<'a> {
    /// FedNL (Alg. 1): plain Newton-type step under `Options::rule`.
    Newton,
    /// FedNL-LS (Alg. 2): Armijo backtracking line search.
    LineSearch(&'a LineSearchParams),
    /// FedNL-PP (Alg. 3): τ-subset participation with a seeded sampler
    /// (the sampler lives here, in the driver — transports only see the
    /// subset).
    PartialParticipation { tau: usize, seed: u64 },
}

/// Buffer-and-commit: replies may arrive in any order, but `commit`
/// sees them in the round's subset order (ascending client id for a
/// full round). Early arrivals wait in `pending`.
pub(crate) struct CommitBuffer {
    /// client id → slot in the subset (usize::MAX = not participating).
    slot_of: Vec<usize>,
    pending: Vec<Option<ClientMsg>>,
    next: usize,
}

impl CommitBuffer {
    pub fn new(n_clients: usize, subset: Option<&[u32]>) -> Self {
        let mut slot_of = vec![usize::MAX; n_clients];
        let m = match subset {
            None => {
                for (i, s) in slot_of.iter_mut().enumerate() {
                    *s = i;
                }
                n_clients
            }
            Some(s) => {
                for (pos, &ci) in s.iter().enumerate() {
                    slot_of[ci as usize] = pos;
                }
                s.len()
            }
        };
        Self {
            slot_of,
            pending: (0..m).map(|_| None).collect(),
            next: 0,
        }
    }

    /// Accept one arrived message; fire `commit` for it and for any
    /// buffered successors whose turn it unblocks.
    pub fn offer(
        &mut self,
        m: ClientMsg,
        mut commit: impl FnMut(&ClientMsg),
    ) {
        let slot = *self
            .slot_of
            .get(m.client_id)
            .expect("client id out of range");
        assert!(
            slot != usize::MAX,
            "reply from non-participating client {}",
            m.client_id
        );
        // A slot below `next` was already committed (and taken back to
        // None), so `is_none()` alone would silently swallow a late
        // duplicate — check both sides of the commit ladder.
        assert!(
            slot >= self.next && self.pending[slot].is_none(),
            "duplicate reply from client {}",
            m.client_id
        );
        self.pending[slot] = Some(m);
        while self.next < self.pending.len() {
            match self.pending[self.next].take() {
                Some(msg) => {
                    commit(&msg);
                    self.next += 1;
                }
                None => break,
            }
        }
    }

    pub fn is_complete(&self) -> bool {
        self.next == self.pending.len()
    }
}

/// Run one member of the FedNL family against any client transport.
pub fn run_engine(
    pool: &mut dyn ClientPool,
    opts: &Options,
    policy: StepPolicy<'_>,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    match policy {
        StepPolicy::PartialParticipation { tau, seed } => {
            run_pp(pool, opts, tau, seed, x0, label)
        }
        _ => run_newton_family(pool, opts, policy, x0, label),
    }
}

/// FedNL / FedNL-LS: full-participation rounds over a [`ServerState`].
fn run_newton_family(
    pool: &mut dyn ClientPool,
    opts: &Options,
    policy: StepPolicy<'_>,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    let ls: Option<&LineSearchParams> = match policy {
        StepPolicy::LineSearch(p) => Some(p),
        _ => None,
    };
    // The unified ROUND/MSG exchange is family-agnostic, so guard here:
    // aggregating a PP client's deltas as absolute gradients would be
    // silently wrong math on any transport.
    assert_eq!(
        pool.family(),
        ClientFamily::FedNL,
        "FedNL/FedNL-LS requires FedNL-family clients, but this pool \
         serves FedNL-PP clients"
    );
    let d = pool.dim();
    let n = pool.n_clients();
    let alpha = opts.alpha.unwrap_or_else(|| pool.default_alpha());
    pool.set_alpha(alpha);
    let mut server = ServerState::new(d, n, alpha, x0);
    let mut trace = Trace::new(label.to_string());
    let sw = Stopwatch::start();
    let mut bytes_up = 0u64;
    let mut bytes_down = 0u64;
    // (seconds blocked waiting for replies, seconds committing them) —
    // the wait/aggregate wall-clock split reported by the coordinator
    // bench.
    let mut timing = (0.0f64, 0.0f64);

    if opts.warm_start {
        let x = server.x.clone();
        bytes_down += wire::vec_frame_bytes(d) * n as u64;
        let packed = pool.warm_start(&x);
        bytes_up += packed
            .iter()
            .map(|p| wire::vec_frame_bytes(p.len()))
            .sum::<u64>();
        server.init_h_from_packed(&packed);
    }

    for round in 0..opts.rounds {
        let x = server.x.clone();
        bytes_down += wire::round_frame_bytes(d) * n as u64;
        // LS always needs fᵢ(xᵏ) (Alg. 2 line 5).
        let need_loss = opts.track_loss || ls.is_some();
        pool.submit_round(&x, None, round, need_loss);
        server.begin_round();
        let mut buf = CommitBuffer::new(n, None);
        drain_and_commit(pool, &mut buf, &mut bytes_up, &mut timing, |m| {
            server.apply_msg(m)
        });
        let (grad, loss) = server.finish_round();
        let gnorm = vector::norm2(&grad);
        let (up, down) =
            pool.transport_bytes().unwrap_or((bytes_up, bytes_down));
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss: loss.unwrap_or(f64::NAN),
            bytes_up: up,
            bytes_down: down,
            elapsed: sw.elapsed_secs(),
        });
        if let Some(tol) = opts.tol_grad {
            if gnorm <= tol {
                break;
            }
        }
        let dir = server.newton_direction(&grad, opts.rule);
        match ls {
            None => {
                // Alg. 1 line 11.
                vector::axpy(1.0, &dir, &mut server.x);
            }
            Some(ls) => {
                // Alg. 2 line 12: backtracking; each probe is one
                // f-reduction over the clients.
                let f_x = loss.expect("LS requires client losses");
                let slope = vector::dot(&grad, &dir); // < 0 for descent
                let mut step = 1.0;
                let mut trial = vec![0.0; d];
                for _bt in 0..=ls.max_backtracks {
                    vector::add_scaled(&server.x, step, &dir, &mut trial);
                    let f_trial = pool.eval_loss(&trial);
                    bytes_down += wire::vec_frame_bytes(d) * n as u64;
                    bytes_up += wire::scalar_frame_bytes() * n as u64;
                    if f_trial <= f_x + ls.c * step * slope {
                        break;
                    }
                    step *= ls.gamma;
                }
                vector::add_scaled(
                    &server.x.clone(),
                    step,
                    &dir,
                    &mut server.x,
                );
            }
        }
    }
    trace.wait_secs = timing.0;
    trace.aggregate_secs = timing.1;
    trace
}

/// FedNL-PP (Alg. 3): the model update happens *before* sampling; the
/// server state (Hᵏ, lᵏ, gᵏ) is persistent and updated incrementally
/// from the participants' deltas.
fn run_pp(
    pool: &mut dyn ClientPool,
    opts: &Options,
    tau: usize,
    seed: u64,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    let n = pool.n_clients();
    assert!(tau >= 1 && tau <= n, "tau must be in [1, n]");
    assert_eq!(
        pool.family(),
        ClientFamily::PP,
        "FedNL-PP requires FedNL-PP-family clients, but this pool \
         serves FedNL clients"
    );
    let d = pool.dim();
    let inv_n = 1.0 / n as f64;
    let alpha = opts.alpha.unwrap_or_else(|| pool.default_alpha());
    pool.set_alpha(alpha);
    // Server init from client initials (line 2), H⁰ = 0.
    let mut h = Mat::zeros(d, d);
    let pu = PackedUpper::new(d);
    let init = pool.init_state();
    let mut l: f64 = init.iter().map(|(li, _)| li).sum::<f64>() * inv_n;
    let mut g = vec![0.0; d];
    for (_, gi) in &init {
        vector::axpy(inv_n, gi, &mut g);
    }
    let mut x = x0;
    let mut trace = Trace::new(label.to_string());
    let sw = Stopwatch::start();
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut bytes_up =
        wire::scalar_vec_frame_bytes(d) * init.len() as u64;
    let mut bytes_down = wire::empty_frame_bytes() * init.len() as u64;
    let mut timing = (0.0f64, 0.0f64);

    for round in 0..opts.rounds {
        // Line 4: xᵏ⁺¹ = (Hᵏ + lᵏI)⁻¹ gᵏ.
        let mut shift = l.max(0.0);
        for _ in 0..60 {
            if let Some(ch) = Cholesky::factor(&h, shift) {
                x = ch.solve_vec(&g);
                break;
            }
            shift = (shift * 2.0).max(1e-12);
        }
        // Lines 5-6: sample Sᵏ, send xᵏ⁺¹ to the τ participants. The
        // seeded sampler lives here in the driver; every transport
        // receives the same subset in the same order.
        let selected = sample_distinct(&mut rng, n, tau);
        bytes_down += wire::round_frame_bytes(d) * tau as u64;
        pool.submit_round(&x, Some(&selected), round, false);
        let mut buf = CommitBuffer::new(n, Some(&selected));
        drain_and_commit(pool, &mut buf, &mut bytes_up, &mut timing, |m| {
            // Lines 18-20: incremental server state, committed in
            // selection order.
            vector::axpy(inv_n, &m.grad, &mut g);
            l += inv_n * m.l_i;
            pu.apply_sparse(
                &mut h,
                alpha * m.update.scale * inv_n,
                &m.update.indices(),
                &m.update.values,
            );
        });
        // Out-of-band convergence measurement at xᵏ⁺¹ (the paper makes
        // the same caveat: ∇f(xᵏ) is not part of PP training). Because
        // this probe is measurement-only, it does NOT count toward the
        // communicated-bytes totals (paper App. E.1 accounting) — and
        // for the same reason the PP trace always reports the logical
        // counters, since a transport's metered totals would include
        // the probe's LOSS_GRAD/GRAD frames.
        let (loss, grad) = pool.loss_grad(&x);
        let gnorm = vector::norm2(&grad);
        let (up, down) = (bytes_up, bytes_down);
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss,
            bytes_up: up,
            bytes_down: down,
            elapsed: sw.elapsed_secs(),
        });
        if let Some(tol) = opts.tol_grad {
            if gnorm <= tol {
                break;
            }
        }
    }
    trace.wait_secs = timing.0;
    trace.aggregate_secs = timing.1;
    trace
}

/// Pump the pool until the round completes, feeding every arrival into
/// the commit buffer. `timing` accumulates (wait, aggregate) seconds.
fn drain_and_commit(
    pool: &mut dyn ClientPool,
    buf: &mut CommitBuffer,
    bytes_up: &mut u64,
    timing: &mut (f64, f64),
    mut commit: impl FnMut(&ClientMsg),
) {
    loop {
        let sw = Stopwatch::start();
        let batch = pool.drain();
        timing.0 += sw.elapsed_secs();
        if batch.is_empty() {
            break;
        }
        let sw = Stopwatch::start();
        for m in batch {
            *bytes_up += m.wire_bytes();
            buf.offer(m, &mut commit);
        }
        timing.1 += sw.elapsed_secs();
    }
    assert!(buf.is_complete(), "round ended with missing client replies");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::{Compressed, IndexPayload, ValueEncoding};

    fn msg(id: usize) -> ClientMsg {
        ClientMsg {
            client_id: id,
            grad: vec![id as f64],
            update: Compressed {
                payload: IndexPayload::Explicit(Vec::new()),
                values: Vec::new(),
                scale: 1.0,
                encoding: ValueEncoding::F64,
                n: 4,
            },
            l_i: 0.0,
            loss: None,
        }
    }

    #[test]
    fn commit_buffer_full_round_commits_in_client_order() {
        let mut buf = CommitBuffer::new(4, None);
        let mut order = Vec::new();
        // Arrival order 2, 0, 3, 1 → commit order 0, 1, 2, 3.
        for id in [2usize, 0, 3, 1] {
            buf.offer(msg(id), |m| order.push(m.client_id));
        }
        assert!(buf.is_complete());
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn commit_buffer_subset_commits_in_selection_order() {
        // Subset [3, 1, 2]: commit order must follow the sampler, not
        // ascending ids (matches the sequential PP reference).
        let subset = [3u32, 1, 2];
        let mut buf = CommitBuffer::new(5, Some(&subset));
        let mut order = Vec::new();
        for id in [2usize, 3, 1] {
            buf.offer(msg(id), |m| order.push(m.client_id));
        }
        assert!(buf.is_complete());
        assert_eq!(order, vec![3, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "non-participating")]
    fn commit_buffer_rejects_foreign_client() {
        let subset = [1u32];
        let mut buf = CommitBuffer::new(3, Some(&subset));
        buf.offer(msg(2), |_| {});
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn commit_buffer_rejects_duplicates() {
        let mut buf = CommitBuffer::new(2, None);
        buf.offer(msg(1), |_| {});
        buf.offer(msg(1), |_| {});
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn commit_buffer_rejects_duplicates_after_commit() {
        // The slot was committed (taken back to None) — the guard must
        // still fire rather than silently re-buffering the message.
        let mut buf = CommitBuffer::new(2, None);
        buf.offer(msg(0), |_| {});
        buf.offer(msg(0), |_| {});
    }
}

//! LIBSVM text format parser over a memory-mapped file (paper §5.2:
//! "moving from sequential I/O to memory-mapped files ... coupled with
//! custom string to FP64 parsing", and Appendix L.2).
//!
//! Format, one sample per line:   `label idx:val idx:val ...`
//! with 1-based feature indices. Parsing never allocates temporary
//! strings (paper v38: "elimination of creating temporary strings").

use anyhow::{bail, Context, Result};

/// A parsed sparse sample.
#[derive(Debug, Clone, PartialEq)]
pub struct LibsvmSample {
    /// Label, normalized to ±1.0 (0/−1 → −1.0, everything > 0 → +1.0).
    pub label: f64,
    /// (0-based feature index, value) pairs in file order.
    pub features: Vec<(u32, f64)>,
}

/// Memory-map a file read-only via `mmap(2)` and parse it.
///
/// Falls back to `std::fs::read` if mapping fails (e.g. special files),
/// so behaviour is identical either way — mapping is purely a systems
/// optimization (paper measured ×1.077 from this step).
pub fn parse_libsvm_file(path: &str) -> Result<(Vec<LibsvmSample>, usize)> {
    let mapped = Mmap::open(path);
    match mapped {
        Ok(m) => parse_libsvm_bytes(m.as_slice())
            .with_context(|| format!("parsing {path}")),
        Err(_) => {
            let bytes = std::fs::read(path)
                .with_context(|| format!("reading {path}"))?;
            parse_libsvm_bytes(&bytes).with_context(|| format!("parsing {path}"))
        }
    }
}

/// Parse LIBSVM-format bytes. Returns (samples, max feature count d_raw).
pub fn parse_libsvm_bytes(bytes: &[u8]) -> Result<(Vec<LibsvmSample>, usize)> {
    let mut samples = Vec::new();
    let mut d_raw = 0usize;
    for (lineno, line) in bytes.split(|&b| b == b'\n').enumerate() {
        let line = trim(line);
        if line.is_empty() || line[0] == b'#' {
            continue;
        }
        let mut cur = Cursor { buf: line, pos: 0 };
        let label_raw = cur
            .parse_f64()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let label = if label_raw > 0.0 { 1.0 } else { -1.0 };
        let mut features = Vec::new();
        loop {
            cur.skip_ws();
            if cur.eof() {
                break;
            }
            let idx = cur
                .parse_u32()
                .with_context(|| format!("line {}: bad index", lineno + 1))?;
            if !cur.eat(b':') {
                bail!("line {}: expected ':' after index", lineno + 1);
            }
            let val = cur
                .parse_f64()
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based", lineno + 1);
            }
            let zero_based = idx - 1;
            d_raw = d_raw.max(idx as usize);
            features.push((zero_based, val));
        }
        samples.push(LibsvmSample { label, features });
    }
    Ok((samples, d_raw))
}

fn trim(mut s: &[u8]) -> &[u8] {
    while let Some((&f, rest)) = s.split_first() {
        if f == b' ' || f == b'\t' || f == b'\r' {
            s = rest;
        } else {
            break;
        }
    }
    while let Some((&l, rest)) = s.split_last() {
        if l == b' ' || l == b'\t' || l == b'\r' {
            s = rest;
        } else {
            break;
        }
    }
    s
}

/// Zero-allocation cursor with custom numeric parsing (paper §5.2
/// "custom string to FP64 parsing").
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn eof(&self) -> bool {
        self.pos >= self.buf.len()
    }

    fn peek(&self) -> Option<u8> {
        self.buf.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn parse_u32(&mut self) -> Result<u32> {
        self.skip_ws();
        let start = self.pos;
        let mut v: u64 = 0;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                v = v * 10 + (c - b'0') as u64;
                if v > u32::MAX as u64 {
                    bail!("index overflow");
                }
                self.pos += 1;
            } else {
                break;
            }
        }
        if self.pos == start {
            bail!("expected integer");
        }
        Ok(v as u32)
    }

    /// Hand-rolled decimal float parser: sign, integer part, fraction,
    /// exponent. Exactly matches `str::parse::<f64>` for round-trippable
    /// inputs up to 1 ULP; LIBSVM values are short decimals where the
    /// accumulation is exact.
    fn parse_f64(&mut self) -> Result<f64> {
        self.skip_ws();
        let start = self.pos;
        let neg = if self.eat(b'-') {
            true
        } else {
            self.eat(b'+');
            false
        };
        let mut mant: f64 = 0.0;
        let mut digits = 0u32;
        let mut any = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                mant = mant * 10.0 + (c - b'0') as f64;
                digits += 1;
                any = true;
                self.pos += 1;
            } else {
                break;
            }
        }
        let mut exp10: i32 = 0;
        if self.eat(b'.') {
            while let Some(c) = self.peek() {
                if c.is_ascii_digit() {
                    mant = mant * 10.0 + (c - b'0') as f64;
                    digits += 1;
                    exp10 -= 1;
                    any = true;
                    self.pos += 1;
                } else {
                    break;
                }
            }
        }
        if !any {
            bail!("expected number");
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            let eneg = if self.eat(b'-') {
                true
            } else {
                self.eat(b'+');
                false
            };
            let mut e: i32 = 0;
            let estart = self.pos;
            while let Some(c) = self.peek() {
                if c.is_ascii_digit() {
                    e = e.saturating_mul(10).saturating_add((c - b'0') as i32);
                    self.pos += 1;
                } else {
                    break;
                }
            }
            if self.pos == estart {
                bail!("expected exponent digits");
            }
            exp10 += if eneg { -e } else { e };
        }
        // For long mantissas / extreme exponents defer to std for exact
        // rounding; the fast path covers typical LIBSVM data. 15
        // significant digits keep the integer mantissa < 2⁵³ (exact).
        let token = &self.buf[start..self.pos];
        if digits > 15 || !(-15..=15).contains(&exp10) {
            // Token includes the sign — return std's exact rounding as-is.
            return std::str::from_utf8(token)
                .ok()
                .and_then(|s| s.parse::<f64>().ok())
                .context("float parse");
        }
        let v = mant * pow10(exp10);
        Ok(if neg { -v } else { v })
    }
}

fn pow10(e: i32) -> f64 {
    const POS: [f64; 19] = [
        1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12,
        1e13, 1e14, 1e15, 1e16, 1e17, 1e18,
    ];
    if e >= 0 {
        POS[e as usize]
    } else {
        1.0 / POS[(-e) as usize]
    }
}

/// Minimal read-only mmap wrapper over `libc::mmap` (Appendix L.2).
pub struct Mmap {
    ptr: *mut libc::c_void,
    len: usize,
}

impl Mmap {
    pub fn open(path: &str) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            return Ok(Self { ptr: std::ptr::null_mut(), len: 0 });
        }
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        // Hint sequential access — the parser streams forward.
        unsafe {
            libc::madvise(ptr, len, libc::MADV_SEQUENTIAL);
        }
        Ok(Self { ptr, len })
    }

    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            &[]
        } else {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            unsafe {
                libc::munmap(self.ptr, self.len);
            }
        }
    }
}

// SAFETY: read-only mapping of an immutable file region.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let text = b"+1 1:0.5 3:-2\n-1 2:1e-3\n";
        let (samples, d) = parse_libsvm_bytes(text).unwrap();
        assert_eq!(d, 3);
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].label, 1.0);
        assert_eq!(samples[0].features, vec![(0, 0.5), (2, -2.0)]);
        assert_eq!(samples[1].label, -1.0);
        assert!((samples[1].features[0].1 - 1e-3).abs() < 1e-18);
    }

    #[test]
    fn label_normalization() {
        let (s, _) = parse_libsvm_bytes(b"0 1:1\n2 1:1\n-1 1:1\n").unwrap();
        assert_eq!(s[0].label, -1.0);
        assert_eq!(s[1].label, 1.0);
        assert_eq!(s[2].label, -1.0);
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let (s, _) =
            parse_libsvm_bytes(b"\n# comment\n+1 1:2.0\n\r\n").unwrap();
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn float_parser_matches_std() {
        let cases = [
            "1", "-1", "0.5", "3.14159", "1e3", "-2.5E-4", "+0.001",
            "123456.789", "9.999999999e17", "1.7976931348623157e308",
        ];
        for c in cases {
            let mut cur = Cursor { buf: c.as_bytes(), pos: 0 };
            let got = cur.parse_f64().unwrap();
            let want: f64 = c.parse().unwrap();
            let tol = want.abs() * 1e-15;
            assert!((got - want).abs() <= tol, "{c}: {got} vs {want}");
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_libsvm_bytes(b"abc 1:2\n").is_err());
        assert!(parse_libsvm_bytes(b"+1 0:2\n").is_err()); // 0-based idx
        assert!(parse_libsvm_bytes(b"+1 3=4\n").is_err());
    }

    #[test]
    fn mmap_roundtrip() {
        let path = std::env::temp_dir().join("fednl_mmap_test.libsvm");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, b"+1 1:1.5 2:-0.5\n-1 1:0.25\n").unwrap();
        let (samples, d) = parse_libsvm_file(&path).unwrap();
        assert_eq!(d, 2);
        assert_eq!(samples.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_ok() {
        let path = std::env::temp_dir().join("fednl_empty_test.libsvm");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, b"").unwrap();
        let (samples, d) = parse_libsvm_file(&path).unwrap();
        assert!(samples.is_empty());
        assert_eq!(d, 0);
        std::fs::remove_file(&path).ok();
    }
}

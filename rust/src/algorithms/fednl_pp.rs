//! FedNL-PP (paper Algorithm 3): partial participation — only a
//! τ-subset Sᵏ of clients, chosen uniformly at random, works each round.
//!
//! The server maintains gᵏ = (1/n)Σ gᵢᵏ, lᵏ = (1/n)Σ lᵢᵏ and
//! Hᵏ = (1/n)Σ Hᵢᵏ incrementally from participant deltas; the model
//! update xᵏ⁺¹ = (Hᵏ + lᵏI)⁻¹ gᵏ happens *before* sampling (line 4).
//! Non-participants change nothing. gᵢ is the "Hessian-corrected local
//! gradient" (Hᵢ + lᵢI)wᵢ − ∇fᵢ(wᵢ), evaluated on the packed Hᵢ without
//! densifying.
//!
//! The trace's ‖∇f(xᵏ)‖ is computed out-of-band over all clients — the
//! paper makes the same caveat ("FedNL-PP lacks explicit support for the
//! computation of ∇f(xᵏ) as part of the training process").

use super::Options;
use crate::compressors::Compressor;
use crate::linalg::packed::PackedUpper;
use crate::linalg::{vector, Cholesky, Mat};
use crate::metrics::{RoundRecord, Trace};
use crate::oracle::Oracle;
use crate::rng::{sample_distinct, Pcg64};
use crate::utils::Stopwatch;

/// Per-client FedNL-PP state (Alg. 3 initialization, line 2).
pub struct PPClientState {
    pub id: usize,
    pub oracle: Box<dyn Oracle>,
    pub compressor: Box<dyn Compressor>,
    pub alpha: f64,
    /// Local model copy wᵢ.
    pub w: Vec<f64>,
    /// Hᵢ packed.
    pub h_shift: Vec<f64>,
    pub l_i: f64,
    pub g_i: Vec<f64>,
    pu: PackedUpper,
    hess: Mat,
    hess_packed: Vec<f64>,
    diff: Vec<f64>,
    grad_buf: Vec<f64>,
}

/// Participant → server message (Alg. 3 line 13).
pub struct PPMsg {
    pub client_id: usize,
    pub update: crate::compressors::Compressed,
    pub dl: f64,
    pub dg: Vec<f64>,
}

impl PPClientState {
    pub fn new(
        id: usize,
        mut oracle: Box<dyn Oracle>,
        compressor: Box<dyn Compressor>,
        alpha: Option<f64>,
        x0: &[f64],
    ) -> Self {
        let d = oracle.dim();
        let pu = PackedUpper::new(d);
        let n = pu.len();
        let alpha = alpha.unwrap_or_else(|| compressor.kind(n).alpha());
        // Initialization with Hᵢ⁰ = 0:
        //   lᵢ⁰ = ‖0 − ∇²fᵢ(x⁰)‖_F, gᵢ⁰ = lᵢ⁰·x⁰ − ∇fᵢ(x⁰).
        let mut hess = Mat::zeros(d, d);
        let mut grad = vec![0.0; d];
        let _ = oracle.loss_grad_hessian(x0, &mut grad, &mut hess);
        let mut hess_packed = vec![0.0; n];
        pu.pack(&hess, &mut hess_packed);
        let l0 = pu.frobenius_sq_packed(&hess_packed).sqrt();
        let mut g0 = vec![0.0; d];
        for i in 0..d {
            g0[i] = l0 * x0[i] - grad[i];
        }
        Self {
            id,
            oracle,
            compressor,
            alpha,
            w: x0.to_vec(),
            h_shift: vec![0.0; n],
            l_i: l0,
            g_i: g0,
            pu,
            hess,
            hess_packed,
            diff: vec![0.0; n],
            grad_buf: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.grad_buf.len()
    }

    /// Participate in round `round` with new model `x` (lines 9–13).
    pub fn participate(&mut self, x: &[f64], round: u64) -> PPMsg {
        let d = self.dim();
        self.w.copy_from_slice(x);
        let _ = self.oracle.loss_grad_hessian(
            x,
            &mut self.grad_buf,
            &mut self.hess,
        );
        self.pu.pack(&self.hess, &mut self.hess_packed);
        vector::sub(&self.hess_packed, &self.h_shift, &mut self.diff);
        let update = self.compressor.compress(&self.pu, &self.diff, round);
        // Hᵢ ← Hᵢ + α·C(∇²fᵢ − Hᵢ) (line 10).
        let a = self.alpha * update.scale;
        for (v, idx) in update.values.iter().zip(update.indices()) {
            self.h_shift[idx as usize] += a * v;
        }
        // lᵢ ← ‖Hᵢ − ∇²fᵢ‖_F (line 11) — recompute on the updated shift.
        vector::sub(&self.h_shift, &self.hess_packed, &mut self.diff);
        let l_new = self.pu.frobenius_sq_packed(&self.diff).sqrt();
        // gᵢ ← (Hᵢ + lᵢI)wᵢ − ∇fᵢ(wᵢ) (line 12), packed matvec.
        let mut g_new = vec![0.0; d];
        self.pu.matvec_packed(&self.h_shift, &self.w, &mut g_new);
        for i in 0..d {
            g_new[i] += l_new * self.w[i] - self.grad_buf[i];
        }
        let dl = l_new - self.l_i;
        let mut dg = vec![0.0; d];
        vector::sub(&g_new, &self.g_i, &mut dg);
        self.l_i = l_new;
        self.g_i = g_new;
        PPMsg { client_id: self.id, update, dl, dg }
    }

    /// Out-of-band full-gradient contribution at `x` (trace only).
    pub fn grad_at(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
        self.oracle.loss_grad(x, g)
    }
}

/// Transport abstraction for FedNL-PP (in-process slice or TCP master).
pub trait PPTransport {
    fn n_clients(&self) -> usize;
    fn dim(&self) -> usize;
    fn default_alpha(&self) -> f64;
    fn set_alpha(&mut self, a: f64);
    /// Collect (lᵢ⁰, gᵢ⁰) from every client (Alg. 3 line 2).
    fn pp_init(&mut self) -> Vec<(f64, Vec<f64>)>;
    /// Run the participant round on the selected clients.
    fn pp_round(&mut self, x: &[f64], round: u64, selected: &[u32])
        -> Vec<PPMsg>;
    /// Out-of-band (f, ∇f) reduction over all clients (trace only).
    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);
    fn transport_bytes(&self) -> Option<(u64, u64)> {
        None
    }
}

/// In-process PP transport over a mutable client slice.
pub struct PPSlice<'a>(pub &'a mut [PPClientState]);

impl PPTransport for PPSlice<'_> {
    fn n_clients(&self) -> usize {
        self.0.len()
    }

    fn dim(&self) -> usize {
        self.0[0].dim()
    }

    fn default_alpha(&self) -> f64 {
        self.0[0].alpha
    }

    fn set_alpha(&mut self, a: f64) {
        for c in self.0.iter_mut() {
            c.alpha = a;
        }
    }

    fn pp_init(&mut self) -> Vec<(f64, Vec<f64>)> {
        self.0.iter().map(|c| (c.l_i, c.g_i.clone())).collect()
    }

    fn pp_round(
        &mut self,
        x: &[f64],
        round: u64,
        selected: &[u32],
    ) -> Vec<PPMsg> {
        selected
            .iter()
            .map(|&ci| self.0[ci as usize].participate(x, round))
            .collect()
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let inv_n = 1.0 / self.0.len() as f64;
        let mut g = vec![0.0; x.len()];
        let mut buf = vec![0.0; x.len()];
        let mut loss = 0.0;
        for c in self.0.iter_mut() {
            loss += c.grad_at(x, &mut buf);
            vector::axpy(inv_n, &buf, &mut g);
        }
        (loss * inv_n, g)
    }
}

/// Run FedNL-PP with `tau` participating clients per round, over any
/// transport.
pub fn run_fednl_pp_transport(
    transport: &mut dyn PPTransport,
    opts: &Options,
    tau: usize,
    seed: u64,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    let n = transport.n_clients();
    assert!(tau >= 1 && tau <= n, "tau must be in [1, n]");
    let d = transport.dim();
    let inv_n = 1.0 / n as f64;
    let alpha = opts.alpha.unwrap_or_else(|| transport.default_alpha());
    transport.set_alpha(alpha);
    // Server init from client initials (line 2), H⁰ = 0.
    let mut h = Mat::zeros(d, d);
    let pu = PackedUpper::new(d);
    let init = transport.pp_init();
    let mut l: f64 = init.iter().map(|(li, _)| li).sum::<f64>() * inv_n;
    let mut g = vec![0.0; d];
    for (_, gi) in &init {
        vector::axpy(inv_n, gi, &mut g);
    }
    let mut x = x0;
    let mut trace = Trace::new(label.to_string());
    let sw = Stopwatch::start();
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut bytes_up = init.len() as u64 * (8 + d as u64 * 8);
    let mut bytes_down = 0u64;

    for round in 0..opts.rounds {
        // Line 4: xᵏ⁺¹ = (Hᵏ + lᵏI)⁻¹ gᵏ.
        let mut shift = l.max(0.0);
        for _ in 0..60 {
            if let Some(ch) = Cholesky::factor(&h, shift) {
                x = ch.solve_vec(&g);
                break;
            }
            shift = (shift * 2.0).max(1e-12);
        }
        // Lines 5-6: sample Sᵏ, send xᵏ⁺¹ to the τ participants.
        let selected = sample_distinct(&mut rng, n, tau);
        bytes_down += (d as u64 * 8) * tau as u64;
        for msg in transport.pp_round(&x, round, &selected) {
            bytes_up += msg.update.wire_bytes() + 8 + msg.dg.len() as u64 * 8;
            // Lines 18-20: incremental server state.
            vector::axpy(inv_n, &msg.dg, &mut g);
            l += inv_n * msg.dl;
            pu.apply_sparse(
                &mut h,
                alpha * msg.update.scale * inv_n,
                &msg.update.indices(),
                &msg.update.values,
            );
        }
        // Out-of-band convergence measurement at xᵏ⁺¹.
        let (loss, grad) = transport.loss_grad(&x);
        let gnorm = vector::norm2(&grad);
        let (up, down) =
            transport.transport_bytes().unwrap_or((bytes_up, bytes_down));
        trace.push(RoundRecord {
            round,
            grad_norm: gnorm,
            loss,
            bytes_up: up,
            bytes_down: down,
            elapsed: sw.elapsed_secs(),
        });
        if let Some(tol) = opts.tol_grad {
            if gnorm <= tol {
                break;
            }
        }
    }
    trace
}

/// Convenience: FedNL-PP over in-process clients.
pub fn run_fednl_pp(
    clients: &mut [PPClientState],
    opts: &Options,
    tau: usize,
    seed: u64,
    x0: Vec<f64>,
) -> Trace {
    assert!(!clients.is_empty());
    let label = format!("FedNL-PP/{}", clients[0].compressor.name());
    run_fednl_pp_transport(&mut PPSlice(clients), opts, tau, seed, x0, &label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::by_name;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn pp_clients(
        n: usize,
        comp: &str,
        seed: u64,
        x0: &[f64],
        d_raw: usize,
    ) -> Vec<PPClientState> {
        let spec = SynthSpec {
            d_raw,
            n_samples: n * 40,
            density: 0.6,
            noise: 1.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        ds.split_even(n)
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                PPClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name(comp, d, 2, seed + i as u64).unwrap(),
                    None,
                    x0,
                )
            })
            .collect()
    }

    #[test]
    fn full_participation_converges() {
        let d = 9;
        let x0 = vec![0.0; d];
        let mut cs = pp_clients(4, "topk", 21, &x0, d - 1);
        let opts = Options { rounds: 120, ..Default::default() };
        let tr = run_fednl_pp(&mut cs, &opts, 4, 1, x0);
        assert!(tr.last_grad_norm() < 1e-8, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn partial_participation_converges_slower_but_converges() {
        let d = 9;
        let x0 = vec![0.0; d];
        let mut full = pp_clients(6, "randk", 22, &x0, d - 1);
        let mut part = pp_clients(6, "randk", 22, &x0, d - 1);
        let opts = Options { rounds: 200, ..Default::default() };
        let tr_full = run_fednl_pp(&mut full, &opts, 6, 2, x0.clone());
        let tr_part = run_fednl_pp(&mut part, &opts, 2, 2, x0);
        assert!(tr_full.last_grad_norm() < 1e-8);
        assert!(tr_part.last_grad_norm() < 1e-5, "partial: {}", tr_part.last_grad_norm());
        // Partial needs more rounds to a fixed tolerance.
        let rf = tr_full.rounds_to_tolerance(1e-6).unwrap();
        let rp = tr_part.rounds_to_tolerance(1e-6).unwrap_or(u64::MAX);
        assert!(rp >= rf, "partial {rp} < full {rf}");
    }

    #[test]
    fn selection_is_seeded_deterministic() {
        let d = 7;
        let x0 = vec![0.0; d];
        let mut a = pp_clients(5, "randseqk", 23, &x0, d - 1);
        let mut b = pp_clients(5, "randseqk", 23, &x0, d - 1);
        let opts = Options { rounds: 30, ..Default::default() };
        let ta = run_fednl_pp(&mut a, &opts, 2, 9, x0.clone());
        let tb = run_fednl_pp(&mut b, &opts, 2, 9, x0);
        for (ra, rb) in ta.records.iter().zip(&tb.records) {
            assert_eq!(ra.grad_norm, rb.grad_norm);
        }
    }
}

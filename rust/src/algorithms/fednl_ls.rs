//! FedNL-LS (paper Algorithm 2): FedNL with backtracking line search —
//! the globalization variant whose step needs no problem constants.
//!
//! Per round, after the usual (streamed, incrementally committed) FedNL
//! aggregation the master computes the search direction
//! dᵏ = −[Hᵏ]⁻¹ ∇f(xᵏ) and finds the smallest s ≥ 0 with the Armijo
//! condition f(xᵏ + γˢ dᵏ) ≤ f(xᵏ) + c·γˢ⟨∇f(xᵏ), dᵏ⟩, each probe
//! costing one f-reduction over the clients (extra communication the
//! paper measures as the ×1.14 slowdown of LS). Defaults c = 0.49,
//! γ = 0.5. The loop itself lives in the unified round engine
//! ([`crate::algorithms::engine`]) under the line-search step policy.

use super::engine::{run_engine, StepPolicy};
use super::{ClientState, Options};
use crate::coordinator::{ClientPool, SlicePool};
use crate::metrics::Trace;

/// Armijo backtracking parameters (c ∈ (0, ½], γ ∈ (0, 1)).
#[derive(Debug, Clone, Copy)]
pub struct LineSearchParams {
    pub c: f64,
    pub gamma: f64,
    /// Cap on backtracking steps per round.
    pub max_backtracks: u32,
}

impl Default for LineSearchParams {
    fn default() -> Self {
        Self { c: 0.49, gamma: 0.5, max_backtracks: 40 }
    }
}

/// Run FedNL-LS against any client transport.
pub fn run_fednl_ls_pool(
    pool: &mut dyn ClientPool,
    opts: &Options,
    ls: &LineSearchParams,
    x0: Vec<f64>,
    label: &str,
) -> Trace {
    run_engine(pool, opts, StepPolicy::LineSearch(ls), x0, label)
}

/// Convenience: FedNL-LS over in-process clients, sequentially.
pub fn run_fednl_ls(
    clients: &mut [ClientState],
    opts: &Options,
    ls: &LineSearchParams,
    x0: Vec<f64>,
) -> Trace {
    assert!(!clients.is_empty());
    let label = format!("FedNL-LS/{}", clients[0].compressor.name());
    run_fednl_ls_pool(&mut SlicePool::new(clients), opts, ls, x0, &label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::by_name;
    use crate::data::{generate_synthetic, Dataset, SynthSpec};
    use crate::oracle::LogisticOracle;

    fn clients(n: usize, comp: &str, seed: u64) -> (Vec<ClientState>, usize) {
        let spec = SynthSpec {
            d_raw: 8,
            n_samples: n * 50,
            density: 0.6,
            noise: 1.0,
            label_bias: 0.0,
            seed,
        };
        let synth = generate_synthetic(&spec);
        let samples: Vec<crate::data::LibsvmSample> = synth
            .labels
            .iter()
            .zip(&synth.rows)
            .map(|(l, r)| crate::data::LibsvmSample {
                label: *l,
                features: r.clone(),
            })
            .collect();
        let ds = Dataset::from_libsvm(&samples, spec.d_raw);
        let d = ds.d;
        let shards = ds.split_even(n).unwrap();
        let cs = shards
            .into_iter()
            .enumerate()
            .map(|(i, sh)| {
                ClientState::new(
                    i,
                    Box::new(LogisticOracle::new(sh, 1e-3)),
                    by_name(comp, d, 2, seed + i as u64).unwrap(),
                    None,
                )
            })
            .collect();
        (cs, d)
    }

    #[test]
    fn converges_with_topk() {
        let (mut cs, d) = clients(4, "topk", 11);
        let opts = Options { rounds: 60, ..Default::default() };
        let tr = run_fednl_ls(
            &mut cs,
            &opts,
            &LineSearchParams::default(),
            vec![0.0; d],
        );
        assert!(tr.last_grad_norm() < 1e-8, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn loss_monotone_nonincreasing() {
        let (mut cs, d) = clients(3, "randseqk", 12);
        let opts = Options { rounds: 40, ..Default::default() };
        let tr = run_fednl_ls(
            &mut cs,
            &opts,
            &LineSearchParams::default(),
            vec![0.0; d],
        );
        for w in tr.records.windows(2) {
            assert!(
                w[1].loss <= w[0].loss + 1e-12,
                "loss rose: {} → {}",
                w[0].loss,
                w[1].loss
            );
        }
    }

    #[test]
    fn converges_from_far_start() {
        let (mut cs, d) = clients(3, "toplek", 13);
        let opts = Options { rounds: 80, ..Default::default() };
        let x0 = vec![5.0; d];
        let tr = run_fednl_ls(&mut cs, &opts, &LineSearchParams::default(), x0);
        assert!(tr.last_grad_norm() < 1e-7, "‖∇f‖={}", tr.last_grad_norm());
    }

    #[test]
    fn threaded_matches_sequential() {
        let (mut c1, d) = clients(5, "natural", 14);
        let (c2, _) = clients(5, "natural", 14);
        let opts = Options { rounds: 20, ..Default::default() };
        let ls = LineSearchParams::default();
        let t1 = run_fednl_ls(&mut c1, &opts, &ls, vec![0.0; d]);
        let mut thr = crate::coordinator::ThreadedPool::new(c2, 2);
        let t2 = run_fednl_ls_pool(&mut thr, &opts, &ls, vec![0.0; d], "x");
        for (a, b) in t1.records.iter().zip(&t2.records) {
            // Every pool reduction (round messages AND line-search
            // eval_loss probes) commits in ascending client-id order,
            // so threaded trajectories are bit-identical to the
            // sequential reference — not merely close.
            assert_eq!(
                a.grad_norm, b.grad_norm,
                "round {}: {} vs {}",
                a.round, a.grad_norm, b.grad_norm
            );
            assert_eq!(a.loss, b.loss, "round {}", a.round);
        }
    }
}

//! Self-contained pseudo-random generation (paper component `random`).
//!
//! Deterministic, seedable PRNGs are *functionally* required by the
//! paper's wire protocol: for RandK/RandSeqK the master reconstructs the
//! sparsification indices from the client's PRG seed instead of
//! receiving them (§7, §9 "we leveraged our implementation's ability to
//! reconstruct indices"). Both sides therefore need a bit-identical
//! generator — hence an in-repo PCG64, not an external crate.

pub mod pcg;

pub use pcg::Pcg64;

/// Minimal RNG interface used across the crate.
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — the standard bit-to-double construction.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift with
    /// rejection (unbiased).
    fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Bernoulli(p) draw.
    fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (used by the synthetic generator).
    fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }
}

/// In-place Fisher–Yates shuffle (paper v12: "shuffle the array in place
/// instead of shuffling a separate array").
pub fn shuffle<T, R: Rng>(rng: &mut R, xs: &mut [T]) {
    let n = xs.len();
    for i in (1..n).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        xs.swap(i, j);
    }
}

/// Sample `k` distinct indices from `[0, n)` u.a.r. via a partial
/// Fisher–Yates with early stopping (paper `random`: "shuffling with
/// early stopping"). O(n) memory, O(k) swaps; the returned indices are
/// in shuffle order (unsorted).
pub fn sample_distinct<R: Rng>(rng: &mut R, n: usize, k: usize) -> Vec<u32> {
    assert!(k <= n, "sample_distinct: k={k} > n={n}");
    let mut idx: Vec<u32> = (0..n as u32).collect();
    for i in 0..k {
        let j = i + rng.next_below((n - i) as u64) as usize;
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg64::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(3);
        let mut xs: Vec<u32> = (0..100).collect();
        shuffle(&mut r, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Pcg64::seed_from_u64(4);
        let s = sample_distinct(&mut r, 50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_distinct_full() {
        let mut r = Pcg64::seed_from_u64(5);
        let mut s = sample_distinct(&mut r, 10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::seed_from_u64(6);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed_from_u64(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }
}

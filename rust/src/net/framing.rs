//! Length-prefixed frames over a TCP stream.
//!
//! Frame layout: `u32 payload_len (LE) | u8 tag | payload`. Writes are
//! buffered and flushed once per frame; reads use `read_exact`. The
//! stream is configured with `TCP_NODELAY` (paper §7: Nagle disabled —
//! frames are explicitly sized, the OS must not delay small ones).
//!
//! Two faces share the codec:
//!
//! * [`Channel`] — the blocking face (clients, relays, the blocking
//!   `RemotePool`). Its write path handles partial writes explicitly:
//!   a `write` may return short, `Interrupted`, or `WouldBlock` (a
//!   socket with `SO_SNDTIMEO`, or one switched to non-blocking mode
//!   by a peer of the event loop) — [`write_full`] retries until the
//!   frame is fully handed to the kernel, so a frame can never be
//!   silently truncated mid-stream.
//! * [`FrameDecoder`] + [`encode_frame`] — the incremental face the
//!   readiness-based `EventPool` drives: bytes arrive in arbitrary
//!   chunks from non-blocking reads and are reassembled into frames;
//!   outbound frames are pre-encoded once (header + payload in one
//!   buffer) and written as far as the socket accepts.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

/// Maximum accepted frame payload (sanity bound: a dense d=2048 Hessian
/// is 32 MiB; anything above 256 MiB is a protocol error).
pub const MAX_FRAME: usize = 256 << 20;

/// Bytes of framing around every payload: u32 length + u8 tag. The
/// drivers' logical byte accounting includes this so it matches the
/// transport's metered counts exactly.
pub const FRAME_HEADER_BYTES: u64 = 5;

/// Encode one complete frame (header + payload) into a single buffer.
/// The event loop pre-encodes every outbound frame this way so a round
/// broadcast is built **once** and shared (`Arc`) across connections,
/// and partial writes resume from a byte offset into one contiguous
/// slice.
pub fn encode_frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME, "frame too large");
    let mut buf = Vec::with_capacity(5 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.push(tag);
    buf.extend_from_slice(payload);
    buf
}

/// Write `buf` to completion on a (nominally) blocking stream,
/// handling the three partial-write outcomes `Write::write` is allowed
/// to produce:
///
/// * a short `Ok(n)` — resume at `buf[n..]`;
/// * `Interrupted` — retry immediately (no bytes were consumed);
/// * `WouldBlock` — the socket has a send timeout, or was left
///   non-blocking by a platform quirk: wait until it is writable and
///   resume. Treating this as an error would desynchronize the frame
///   stream after a *partial* header/payload write.
///
/// `Ok(0)` from a non-empty buffer means the peer is gone — an error,
/// not a silent truncation.
pub fn write_full(stream: &mut TcpStream, buf: &[u8]) -> Result<()> {
    let mut off = 0;
    while off < buf.len() {
        match stream.write(&buf[off..]) {
            Ok(0) => anyhow::bail!("write returned 0: peer closed"),
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                super::sys::wait_writable(stream)?;
            }
            Err(e) => return Err(e).context("frame write"),
        }
    }
    Ok(())
}

/// A framed, metered TCP channel.
pub struct Channel {
    stream: TcpStream,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

impl Channel {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(Self { stream, bytes_sent: 0, bytes_received: 0 })
    }

    pub fn send(&mut self, tag: u8, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(payload.len() <= MAX_FRAME, "frame too large");
        let mut header = [0u8; 5];
        header[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
        header[4] = tag;
        // Explicit partial-write handling (write_full) — a short write
        // must resume, never silently truncate the frame stream.
        write_full(&mut self.stream, &header)?;
        write_full(&mut self.stream, payload)?;
        self.stream.flush()?;
        self.bytes_sent += FRAME_HEADER_BYTES + payload.len() as u64;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<(u8, Vec<u8>)> {
        let mut header = [0u8; 5];
        self.stream.read_exact(&mut header).context("frame header")?;
        let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
        let tag = header[4];
        anyhow::ensure!(len <= MAX_FRAME, "oversized frame: {len}");
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload).context("frame payload")?;
        self.bytes_received += FRAME_HEADER_BYTES + len as u64;
        Ok((tag, payload))
    }

    /// Bound the time a blocking [`Channel::recv`] may wait (`None` =
    /// wait forever). A timeout mid-frame desynchronizes the stream, so
    /// callers that hit one must retire the channel — `RemotePool`
    /// deregisters the client (the per-client reply deadline).
    pub fn set_read_timeout(&self, dur: Option<std::time::Duration>) -> Result<()> {
        self.stream.set_read_timeout(dur).context("set_read_timeout")?;
        Ok(())
    }

    /// Non-destructive liveness probe: has the peer closed its end?
    /// Flips the socket non-blocking for one `MSG_PEEK` — `Ok(0)` is
    /// EOF, pending bytes or `WouldBlock` mean the peer is alive, any
    /// other error means the connection is gone. `RelayPool` sweeps
    /// this at the top of `prepare_round` so a relay that died since
    /// the last round is certified *before* the round is submitted —
    /// the same round the loss becomes visible on in-process pools —
    /// instead of surfacing as a silent zero-reply partition at
    /// deadline expiry.
    pub fn peek_eof(&self) -> bool {
        if self.stream.set_nonblocking(true).is_err() {
            return true;
        }
        let mut probe = [0u8; 1];
        let dead = match self.stream.peek(&mut probe) {
            Ok(0) => true,
            Ok(_) => false,
            Err(e) if e.kind() == ErrorKind::WouldBlock => false,
            Err(e) if e.kind() == ErrorKind::Interrupted => false,
            Err(_) => true,
        };
        if self.stream.set_nonblocking(false).is_err() {
            return true;
        }
        dead
    }

    pub fn peer_addr(&self) -> String {
        self.stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into())
    }

    /// Surrender the raw stream plus the byte meters accumulated so
    /// far. The event loop admits connections through a blocking
    /// [`Channel`] handshake, then takes the socket over into its
    /// non-blocking state machine — seeding the connection's meters
    /// with the handshake bytes keeps `transport_bytes` cumulative.
    pub fn into_parts(self) -> (TcpStream, u64, u64) {
        (self.stream, self.bytes_sent, self.bytes_received)
    }
}

/// Incremental frame reassembly for non-blocking reads.
///
/// The event loop reads whatever the socket has into a shared scratch
/// buffer and feeds it here; the decoder buffers a partial header in a
/// 5-byte array and allocates the payload buffer **lazily** (only once
/// a header announces a frame, sized exactly to it, released when the
/// frame completes) — an idle connection holds no payload memory,
/// which is what keeps per-idle-client server memory flat.
#[derive(Default)]
pub struct FrameDecoder {
    header: [u8; 5],
    header_len: usize,
    payload: Vec<u8>,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one chunk; returns every frame completed by it (possibly
    /// none, possibly several). A frame announcing more than
    /// [`MAX_FRAME`] bytes is a protocol error — the caller retires
    /// the connection.
    pub fn push(
        &mut self,
        mut chunk: &[u8],
    ) -> Result<Vec<(u8, Vec<u8>)>> {
        let mut out = Vec::new();
        while !chunk.is_empty() {
            if self.header_len < 5 {
                let take = (5 - self.header_len).min(chunk.len());
                self.header[self.header_len..self.header_len + take]
                    .copy_from_slice(&chunk[..take]);
                self.header_len += take;
                chunk = &chunk[take..];
                if self.header_len < 5 {
                    break;
                }
                let len = u32::from_le_bytes(
                    self.header[..4].try_into().unwrap(),
                ) as usize;
                anyhow::ensure!(len <= MAX_FRAME, "oversized frame: {len}");
                self.payload = Vec::with_capacity(len);
            }
            let need = self.announced_len() - self.payload.len();
            let take = need.min(chunk.len());
            self.payload.extend_from_slice(&chunk[..take]);
            chunk = &chunk[take..];
            if self.payload.len() == self.announced_len() {
                let tag = self.header[4];
                out.push((tag, std::mem::take(&mut self.payload)));
                self.header_len = 0;
            }
        }
        Ok(out)
    }

    fn announced_len(&self) -> usize {
        debug_assert_eq!(self.header_len, 5);
        u32::from_le_bytes(self.header[..4].try_into().unwrap()) as usize
    }

    /// True between frames: no partial header or payload buffered.
    /// EOF while mid-frame is a truncation, not a clean close.
    pub fn is_idle(&self) -> bool {
        self.header_len == 0
    }

    /// Bytes of buffered partial-frame state (the idle-memory meter).
    pub fn buffered_bytes(&self) -> usize {
        self.header_len + self.payload.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn roundtrip_frames() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut ch = Channel::new(s).unwrap();
            let (tag, p) = ch.recv().unwrap();
            assert_eq!(tag, 7);
            ch.send(8, &p).unwrap(); // echo
        });
        let mut ch = Channel::new(TcpStream::connect(addr).unwrap()).unwrap();
        let payload = vec![1u8, 2, 3, 4, 5];
        ch.send(7, &payload).unwrap();
        let (tag, echoed) = ch.recv().unwrap();
        assert_eq!(tag, 8);
        assert_eq!(echoed, payload);
        assert_eq!(ch.bytes_sent, 10);
        assert_eq!(ch.bytes_received, 10);
        t.join().unwrap();
    }

    #[test]
    fn empty_payload_ok() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut ch = Channel::new(s).unwrap();
            let (tag, p) = ch.recv().unwrap();
            assert_eq!(tag, 1);
            assert!(p.is_empty());
        });
        let mut ch = Channel::new(TcpStream::connect(addr).unwrap()).unwrap();
        ch.send(1, &[]).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn decoder_reassembles_byte_by_byte() {
        // Worst-case delivery: every byte in its own chunk, two frames
        // back to back (incl. an empty payload).
        let mut stream = encode_frame(7, &[1, 2, 3]);
        stream.extend_from_slice(&encode_frame(9, &[]));
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for &b in &stream {
            got.extend(dec.push(&[b]).unwrap());
        }
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (7, vec![1, 2, 3]));
        assert_eq!(got[1], (9, Vec::new()));
        assert!(dec.is_idle());
        assert_eq!(dec.buffered_bytes(), 0);
    }

    #[test]
    fn decoder_split_across_header_and_payload() {
        // One chunk ends exactly at the header boundary, the next
        // carries the payload plus the start of a second frame.
        let f1 = encode_frame(3, &[10, 20, 30, 40]);
        let f2 = encode_frame(4, &[99]);
        let mut dec = FrameDecoder::new();
        assert!(dec.push(&f1[..5]).unwrap().is_empty());
        assert!(!dec.is_idle());
        let mut rest = f1[5..].to_vec();
        rest.extend_from_slice(&f2);
        let got = dec.push(&rest).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (3, vec![10, 20, 30, 40]));
        assert_eq!(got[1], (4, vec![99]));
        assert!(dec.is_idle());
    }

    #[test]
    fn decoder_multiple_frames_one_chunk() {
        let mut stream = Vec::new();
        for tag in 0..5u8 {
            stream.extend_from_slice(&encode_frame(tag, &[tag; 3]));
        }
        let got = FrameDecoder::new().push(&stream).unwrap();
        assert_eq!(got.len(), 5);
        for (tag, p) in got {
            assert_eq!(p, vec![tag; 3]);
        }
    }

    #[test]
    fn decoder_rejects_oversized_frame() {
        let mut header = [0u8; 5];
        header[..4]
            .copy_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        let mut dec = FrameDecoder::new();
        assert!(dec.push(&header).is_err());
    }

    #[test]
    fn peek_eof_detects_closed_peer_without_consuming() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (s, _) = listener.accept().unwrap();
        let mut server = Channel::new(s).unwrap();
        let mut client = Channel::new(client).unwrap();
        // Live, idle peer: not EOF.
        assert!(!server.peek_eof());
        // Pending bytes: still not EOF, and the probe must not consume
        // them — the frame is read back intact afterwards.
        client.send(7, &[1, 2, 3]).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!server.peek_eof());
        let (tag, p) = server.recv().unwrap();
        assert_eq!((tag, p), (7, vec![1, 2, 3]));
        // Closed peer: EOF.
        drop(client);
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(server.peek_eof());
    }

    #[test]
    fn encode_frame_matches_channel_wire_format() {
        // Channel::recv must accept what encode_frame produces: send a
        // pre-encoded frame as raw bytes, read it back as a frame.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut ch = Channel::new(s).unwrap();
            ch.recv().unwrap()
        });
        let mut s = TcpStream::connect(addr).unwrap();
        write_full(&mut s, &encode_frame(42, &[5, 6, 7])).unwrap();
        let (tag, p) = t.join().unwrap();
        assert_eq!(tag, 42);
        assert_eq!(p, vec![5, 6, 7]);
    }
}

//! Runtime-dispatched SIMD kernels for the FedNL hot path.
//!
//! The paper's ×1000 speedup program (§5) bottoms out in a handful of
//! dense f64 primitives: dot products and AXPYs (margins, gradients,
//! solvers), the symmetric rank-1 Hessian accumulate (§5.10, ×3.07),
//! the fused sigmoid pass (§5.7, ×1.50) and the |value|²-weighted scans
//! the sparsifying compressors run every round (§5.11). This module
//! implements each primitive twice:
//!
//! * an **AVX2+FMA** path (`core::arch::x86_64` intrinsics) selected at
//!   runtime via `is_x86_feature_detected!` — no compile-time feature
//!   flags, so one binary runs everywhere and uses the wide units when
//!   they exist (the portable analogue of the paper's AVX-512 build);
//! * a **portable scalar** path ([`scalar`]), 4-way unrolled with
//!   independent accumulators so LLVM can autovectorize to whatever the
//!   baseline target offers (SSE2 on x86-64, NEON on aarch64).
//!
//! Dispatch is resolved once per process and cached in an atomic, so a
//! kernel call costs one relaxed load on top of the work itself.
//!
//! **Determinism contract:** for a fixed ISA decision every kernel
//! reduces in a fixed order (fixed lane count, fixed accumulator tree),
//! so repeated runs on the same machine produce bit-identical results —
//! the property [`crate::coordinator::ThreadedPool`] relies on for
//! bit-reproducible trajectories. The AVX2 and scalar paths may differ
//! from each other by normal floating-point reassociation (tests bound
//! this by an n·ε-scaled tolerance), but each path is individually
//! deterministic.

use std::sync::atomic::{AtomicU8, Ordering};

const ISA_UNKNOWN: u8 = 0;
const ISA_SCALAR: u8 = 1;
const ISA_AVX2: u8 = 2;

static ISA: AtomicU8 = AtomicU8::new(ISA_UNKNOWN);

/// CI / debugging override: `FEDNL_FORCE_SCALAR=1` (any value other
/// than `0` / empty) pins the dispatcher to the portable scalar path
/// even on AVX2 hosts, so both ISA paths get exercised on every PR.
fn force_scalar_env() -> bool {
    match std::env::var_os("FEDNL_FORCE_SCALAR") {
        Some(v) => !v.is_empty() && v != "0",
        None => false,
    }
}

#[cold]
fn detect() -> u8 {
    let isa = if force_scalar_env() {
        ISA_SCALAR
    } else {
        detect_hw()
    };
    ISA.store(isa, Ordering::Relaxed);
    isa
}

#[cfg(target_arch = "x86_64")]
fn detect_hw() -> u8 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        ISA_AVX2
    } else {
        ISA_SCALAR
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_hw() -> u8 {
    ISA_SCALAR
}

#[inline(always)]
fn use_avx2() -> bool {
    let isa = ISA.load(Ordering::Relaxed);
    if isa == ISA_UNKNOWN {
        return detect() == ISA_AVX2;
    }
    isa == ISA_AVX2
}

/// Name of the dispatched instruction set ("avx2" or "scalar") — used
/// by benches and `BENCH_kernels.json`.
pub fn isa_name() -> &'static str {
    if use_avx2() {
        "avx2"
    } else {
        "scalar"
    }
}

// ---------------------------------------------------------------------
// Dispatched entry points.
// ---------------------------------------------------------------------

/// Dot product `Σ a_i·b_i`.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    // Release-mode check: the AVX2 path does raw loads sized by `a`.
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            return unsafe { avx2::dot(a, b) };
        }
    }
    scalar::dot(a, b)
}

/// `y += alpha * x` (AXPY).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    // Release-mode check: the AVX2 path does raw stores sized by `x`.
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            unsafe { avx2::axpy(alpha, x, y) };
            return;
        }
    }
    scalar::axpy(alpha, x, y)
}

/// Squared Euclidean norm `Σ x_i²`.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// `out = a + alpha * b` (fused vector-vector, paper v42).
#[inline]
pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64], out: &mut [f64]) {
    assert!(a.len() == b.len() && b.len() == out.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            unsafe { avx2::add_scaled(a, alpha, b, out) };
            return;
        }
    }
    scalar::add_scaled(a, alpha, b, out)
}

/// `max_i |x_i|` (ℓ∞ scan; compressor prefilters and `norm_inf`).
#[inline]
pub fn abs_max(x: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            return unsafe { avx2::abs_max(x) };
        }
    }
    scalar::abs_max(x)
}

/// Elementwise energy scan `out_i = w_i · v_i²` — the Frobenius-weighted
/// magnitude pass TopK/TopLEK selection runs over the packed upper
/// triangle every round (§5.11).
#[inline]
pub fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
    assert!(w.len() == v.len() && v.len() == out.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            unsafe { avx2::energy_scan(w, v, out) };
            return;
        }
    }
    scalar::energy_scan(w, v, out)
}

/// Weighted squared norm `Σ w_i · v_i²` (packed Frobenius accounting).
#[inline]
pub fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
    assert_eq!(w.len(), v.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            return unsafe { avx2::weighted_norm2_sq(w, v) };
        }
    }
    scalar::weighted_norm2_sq(w, v)
}

/// Logistic-Hessian weight scan `out_i = scale · s_i · (1 − s_i)` from
/// cached sigmoids (§5.7: σ(z)σ(−z) derived from one σ evaluation).
#[inline]
pub fn sigmoid_variance_scan(s: &[f64], scale: f64, out: &mut [f64]) {
    assert_eq!(s.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            unsafe { avx2::sigmoid_variance_scan(s, scale, out) };
            return;
        }
    }
    scalar::sigmoid_variance_scan(s, scale, out)
}

/// Symmetric rank-1 accumulate over the upper triangle (§5.10):
/// `data[u·d + v] += Σ_b h_b · a_b[u] · a_b[v]` for `u ≤ v`, processing
/// 4 samples per sweep. `data` is the row-major buffer of a d×d matrix;
/// `samples` are row slices of length d. The single hottest kernel in
/// FedNL — the AVX2 path runs 4 FMAs per 4 columns.
pub fn sym_rank1_upper(
    data: &mut [f64],
    d: usize,
    samples: &[&[f64]],
    h: &[f64],
) {
    // Release-mode checks: the AVX2 path reads d elements per sample
    // and writes rows of `data` through raw pointers.
    assert_eq!(data.len(), d * d);
    sym_rank1_upper_rows(data, d, 0, d, samples, h)
}

/// Row-ranged rank-1 accumulate: `block` holds rows `u0..u1` of a d×d
/// row-major matrix and receives `block[(u−u0)·d + v] += Σ_b h_b ·
/// a_b[u] · a_b[v]` for `u0 ≤ u < u1`, `u ≤ v`. The building block of
/// [`sym_rank1_upper_threaded`]; per-entry accumulation order is
/// identical to [`sym_rank1_upper`].
pub fn sym_rank1_upper_rows(
    block: &mut [f64],
    d: usize,
    u0: usize,
    u1: usize,
    samples: &[&[f64]],
    h: &[f64],
) {
    assert!(u0 <= u1 && u1 <= d);
    assert_eq!(block.len(), (u1 - u0) * d);
    assert_eq!(samples.len(), h.len());
    assert!(samples.iter().all(|s| s.len() == d));
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            unsafe { avx2::sym_rank1_upper_rows(block, d, u0, u1, samples, h) };
            return;
        }
    }
    scalar::sym_rank1_upper_rows(block, d, u0, u1, samples, h)
}

/// Multi-threaded rank-1 accumulate (the ROADMAP's "thread the §5.10
/// accumulate across samples *within* one client"): the packed upper
/// triangle is partitioned into contiguous **row blocks** of roughly
/// equal triangle area, one scoped thread per block, each sweeping all
/// samples over its own rows. Every matrix entry is written by exactly
/// one thread with the same per-sample accumulation order as the
/// single-threaded kernel, so the result is **bit-identical for any
/// thread count** — trajectories do not change when intra-client
/// threading is enabled.
pub fn sym_rank1_upper_threaded(
    data: &mut [f64],
    d: usize,
    samples: &[&[f64]],
    h: &[f64],
    n_threads: usize,
) {
    assert_eq!(data.len(), d * d);
    assert_eq!(samples.len(), h.len());
    assert!(samples.iter().all(|s| s.len() == d));
    let t = n_threads.max(1).min(d.max(1));
    // Tiny problems: the spawn overhead dwarfs the work.
    if t == 1 || d < 32 {
        return sym_rank1_upper_rows(data, d, 0, d, samples, h);
    }
    let bounds = triangle_row_blocks(d, t);
    std::thread::scope(|scope| {
        let mut rest: &mut [f64] = data;
        for w in bounds.windows(2) {
            let (u0, u1) = (w[0], w[1]);
            if u0 == u1 {
                continue;
            }
            let r = std::mem::take(&mut rest);
            let (block, tail) = r.split_at_mut((u1 - u0) * d);
            rest = tail;
            scope.spawn(move || {
                sym_rank1_upper_rows(block, d, u0, u1, samples, h)
            });
        }
    });
}

/// Partition rows `0..d` into `t` contiguous blocks with approximately
/// equal upper-triangle area (row u owns d−u entries). Returns t+1
/// boundaries starting at 0 and ending at d; deterministic in (d, t).
fn triangle_row_blocks(d: usize, t: usize) -> Vec<usize> {
    let total = d * (d + 1) / 2;
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0);
    let mut acc = 0usize;
    let mut next = 1usize;
    for u in 0..d {
        acc += d - u;
        if next < t && acc * t >= total * next {
            bounds.push(u + 1);
            next += 1;
        }
    }
    while bounds.len() < t + 1 {
        bounds.push(d);
    }
    bounds
}

/// Intra-client threads for the rank-1 Hessian accumulate (1 = off,
/// the default — client-level parallelism via `ThreadedPool` already
/// saturates multi-core hosts; raise it for few-client / sequential
/// runs, e.g. `fednl train --intra-threads N`).
static INTRA_THREADS: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(1);

pub fn set_intra_threads(n: usize) {
    INTRA_THREADS.store(n.max(1), Ordering::Relaxed);
}

pub fn intra_threads() -> usize {
    INTRA_THREADS.load(Ordering::Relaxed)
}

/// Bulk superaccumulate (reproducible-summation layer, see
/// [`crate::linalg::reduce`]): fold every element of `xs` into the
/// fixed-point accumulator `limbs`, returning the accumulated
/// special-value mask (`reduce::SP_*` bits) for the non-finite terms.
///
/// Unlike the float kernels above, the arithmetic here is **integer
/// exact**, so the AVX2 and scalar paths produce bit-identical limbs —
/// dispatch affects throughput only, never the sum. The kernel
/// carry-propagates internally and leaves `limbs` in canonical form.
#[inline]
pub fn binned_accumulate(
    limbs: &mut [i64; super::reduce::LIMBS],
    xs: &[f64],
) -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if use_avx2() {
            return unsafe { avx2::binned_accumulate(limbs, xs) };
        }
    }
    scalar::binned_accumulate(limbs, xs)
}

/// Chunk length between carry propagations inside the bulk kernels
/// (each term adds < 2^32 to a limb; 2^28 chunks keep limbs far from
/// i64 overflow even on top of canonical state).
const BINNED_CHUNK: usize = 1 << 28;

/// Wrap-around contiguous gather: `out = src[(start + t) mod n]` for
/// `t = 0..k` — at most two `memcpy`s (RandSeqK's cache-aware selection,
/// paper App. C.4).
#[inline]
pub fn gather_window(
    src: &[f64],
    start: usize,
    k: usize,
    out: &mut Vec<f64>,
) {
    let n = src.len();
    debug_assert!(start < n && k <= n);
    out.clear();
    let first = (n - start).min(k);
    out.extend_from_slice(&src[start..start + first]);
    out.extend_from_slice(&src[..k - first]);
}

// ---------------------------------------------------------------------
// Portable scalar fallbacks (4-way unrolled, autovectorizer-friendly).
// ---------------------------------------------------------------------

/// Reference implementations: manually unrolled scalar loops with
/// independent accumulators (paper v32). Public so benches can A/B the
/// dispatched path against them and tests can bound the divergence.
pub mod scalar {
    /// Dot product with 4 independent accumulators.
    #[inline]
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in chunks * 4..n {
            s += a[i] * b[i];
        }
        s
    }

    /// `y += alpha * x`.
    #[inline]
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x.iter()) {
            *yi += alpha * *xi;
        }
    }

    /// `out = a + alpha * b`.
    #[inline]
    pub fn add_scaled(a: &[f64], alpha: f64, b: &[f64], out: &mut [f64]) {
        for i in 0..a.len() {
            out[i] = a[i] + alpha * b[i];
        }
    }

    /// `max |x_i|`.
    #[inline]
    pub fn abs_max(x: &[f64]) -> f64 {
        x.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// `out_i = w_i · v_i²`.
    #[inline]
    pub fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
        for i in 0..v.len() {
            out[i] = w[i] * (v[i] * v[i]);
        }
    }

    /// `Σ w_i · v_i²` with 4 independent accumulators.
    #[inline]
    pub fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
        let n = v.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for c in 0..chunks {
            let i = c * 4;
            s0 += w[i] * (v[i] * v[i]);
            s1 += w[i + 1] * (v[i + 1] * v[i + 1]);
            s2 += w[i + 2] * (v[i + 2] * v[i + 2]);
            s3 += w[i + 3] * (v[i + 3] * v[i + 3]);
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in chunks * 4..n {
            s += w[i] * (v[i] * v[i]);
        }
        s
    }

    /// `out_i = scale · s_i · (1 − s_i)`.
    #[inline]
    pub fn sigmoid_variance_scan(s: &[f64], scale: f64, out: &mut [f64]) {
        for i in 0..s.len() {
            out[i] = scale * (s[i] * (1.0 - s[i]));
        }
    }

    /// Bulk superaccumulate, 4-way unrolled (exact integer scatter;
    /// see the dispatched [`super::binned_accumulate`]). The unroll
    /// overlaps the four independent decomposes — the limb adds are
    /// order-free because integer addition is associative.
    pub fn binned_accumulate(
        limbs: &mut [i64; crate::linalg::reduce::LIMBS],
        xs: &[f64],
    ) -> u8 {
        use crate::linalg::reduce::{accumulate_one, propagate_limbs};
        let mut special = 0u8;
        for chunk in xs.chunks(super::BINNED_CHUNK) {
            let mut i = 0;
            while i + 4 <= chunk.len() {
                special |= accumulate_one(limbs, chunk[i]);
                special |= accumulate_one(limbs, chunk[i + 1]);
                special |= accumulate_one(limbs, chunk[i + 2]);
                special |= accumulate_one(limbs, chunk[i + 3]);
                i += 4;
            }
            while i < chunk.len() {
                special |= accumulate_one(limbs, chunk[i]);
                i += 1;
            }
            propagate_limbs(limbs);
        }
        if xs.is_empty() {
            propagate_limbs(limbs);
        }
        special
    }

    /// Upper-triangle rank-1 accumulate, 4 samples per sweep with four
    /// independent scalar chains (paper v26+v52).
    pub fn sym_rank1_upper(
        data: &mut [f64],
        d: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        sym_rank1_upper_rows(data, d, 0, d, samples, h)
    }

    /// Row-ranged variant of [`sym_rank1_upper`]: accumulates rows
    /// `u0..u1` only, with `block` holding exactly those rows
    /// (`block.len() == (u1 − u0) · d`). The per-entry accumulation
    /// order is identical to the full kernel — the row partition of the
    /// threaded accumulate stays bit-identical to single-threaded.
    pub fn sym_rank1_upper_rows(
        block: &mut [f64],
        d: usize,
        u0: usize,
        u1: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        debug_assert_eq!(block.len(), (u1 - u0) * d);
        let mut b = 0;
        while b + 4 <= samples.len() {
            let (a0, a1, a2, a3) =
                (samples[b], samples[b + 1], samples[b + 2], samples[b + 3]);
            let (h0, h1, h2, h3) = (h[b], h[b + 1], h[b + 2], h[b + 3]);
            for u in u0..u1 {
                let c0 = h0 * a0[u];
                let c1 = h1 * a1[u];
                let c2 = h2 * a2[u];
                let c3 = h3 * a3[u];
                let r = u - u0;
                let row = &mut block[r * d..(r + 1) * d];
                for v in u..d {
                    row[v] +=
                        c0 * a0[v] + c1 * a1[v] + c2 * a2[v] + c3 * a3[v];
                }
            }
            b += 4;
        }
        while b < samples.len() {
            let a = samples[b];
            let hb = h[b];
            for u in u0..u1 {
                let c = hb * a[u];
                let r = u - u0;
                let row = &mut block[r * d..(r + 1) * d];
                for v in u..d {
                    row[v] += c * a[v];
                }
            }
            b += 1;
        }
    }
}

// ---------------------------------------------------------------------
// AVX2 + FMA path (x86-64 only; entered only after runtime detection).
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Horizontal sum of a 256-bit lane in a fixed order:
    /// (l0 + l1) + (l2 + l3).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), v);
        (buf[0] + buf[1]) + (buf[2] + buf[3])
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut acc2 = _mm256_setzero_pd();
        let mut acc3 = _mm256_setzero_pd();
        let mut i = 0;
        // 16 doubles per iteration: 4 independent FMA chains.
        while i + 16 <= n {
            acc0 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i)),
                _mm256_loadu_pd(pb.add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 4)),
                _mm256_loadu_pd(pb.add(i + 4)),
                acc1,
            );
            acc2 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 8)),
                _mm256_loadu_pd(pb.add(i + 8)),
                acc2,
            );
            acc3 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i + 12)),
                _mm256_loadu_pd(pb.add(i + 12)),
                acc3,
            );
            i += 16;
        }
        while i + 4 <= n {
            acc0 = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(i)),
                _mm256_loadu_pd(pb.add(i)),
                acc0,
            );
            i += 4;
        }
        // Fixed combination order → deterministic reduction.
        let acc = _mm256_add_pd(
            _mm256_add_pd(acc0, acc1),
            _mm256_add_pd(acc2, acc3),
        );
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len();
        let va = _mm256_set1_pd(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let y0 = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(px.add(i)),
                _mm256_loadu_pd(py.add(i)),
            );
            let y1 = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(px.add(i + 4)),
                _mm256_loadu_pd(py.add(i + 4)),
            );
            _mm256_storeu_pd(py.add(i), y0);
            _mm256_storeu_pd(py.add(i + 4), y1);
            i += 8;
        }
        while i + 4 <= n {
            let y0 = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(px.add(i)),
                _mm256_loadu_pd(py.add(i)),
            );
            _mm256_storeu_pd(py.add(i), y0);
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn add_scaled(
        a: &[f64],
        alpha: f64,
        b: &[f64],
        out: &mut [f64],
    ) {
        let n = a.len();
        let va = _mm256_set1_pd(alpha);
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let o = _mm256_fmadd_pd(
                va,
                _mm256_loadu_pd(pb.add(i)),
                _mm256_loadu_pd(pa.add(i)),
            );
            _mm256_storeu_pd(po.add(i), o);
            i += 4;
        }
        while i < n {
            out[i] = a[i] + alpha * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn abs_max(x: &[f64]) -> f64 {
        let n = x.len();
        let px = x.as_ptr();
        // Clear the sign bit instead of computing |x| lane by lane.
        let mask = _mm256_castsi256_pd(_mm256_set1_epi64x(i64::MAX));
        let mut m = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let v = _mm256_and_pd(mask, _mm256_loadu_pd(px.add(i)));
            // Operand order matters: VMAXPD returns the *second* operand
            // on NaN, so keeping the accumulator there makes NaN inputs
            // transparent — same semantics as scalar `f64::max`.
            m = _mm256_max_pd(v, m);
            i += 4;
        }
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), m);
        let mut s = buf[0].max(buf[1]).max(buf[2]).max(buf[3]);
        while i < n {
            s = s.max(x[i].abs());
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn energy_scan(w: &[f64], v: &[f64], out: &mut [f64]) {
        let n = v.len();
        let (pw, pv) = (w.as_ptr(), v.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let vv = _mm256_loadu_pd(pv.add(i));
            let e =
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), _mm256_mul_pd(vv, vv));
            _mm256_storeu_pd(po.add(i), e);
            i += 4;
        }
        while i < n {
            out[i] = w[i] * (v[i] * v[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn weighted_norm2_sq(w: &[f64], v: &[f64]) -> f64 {
        let n = v.len();
        let (pw, pv) = (w.as_ptr(), v.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            let v0 = _mm256_loadu_pd(pv.add(i));
            let v1 = _mm256_loadu_pd(pv.add(i + 4));
            acc0 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), v0),
                v0,
                acc0,
            );
            acc1 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i + 4)), v1),
                v1,
                acc1,
            );
            i += 8;
        }
        while i + 4 <= n {
            let v0 = _mm256_loadu_pd(pv.add(i));
            acc0 = _mm256_fmadd_pd(
                _mm256_mul_pd(_mm256_loadu_pd(pw.add(i)), v0),
                v0,
                acc0,
            );
            i += 4;
        }
        let mut s = hsum(_mm256_add_pd(acc0, acc1));
        while i < n {
            s += w[i] * (v[i] * v[i]);
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sigmoid_variance_scan(
        s: &[f64],
        scale: f64,
        out: &mut [f64],
    ) {
        let n = s.len();
        let vscale = _mm256_set1_pd(scale);
        let one = _mm256_set1_pd(1.0);
        let ps = s.as_ptr();
        let po = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let sv = _mm256_loadu_pd(ps.add(i));
            let t = _mm256_mul_pd(sv, _mm256_sub_pd(one, sv));
            _mm256_storeu_pd(po.add(i), _mm256_mul_pd(vscale, t));
            i += 4;
        }
        while i < n {
            out[i] = scale * (s[i] * (1.0 - s[i]));
            i += 1;
        }
    }

    /// Bulk superaccumulate, AVX2-assisted: the (exponent, mantissa,
    /// sign) decompose of 4 lanes runs on the integer units, the limb
    /// scatter stays scalar (it is a data-dependent 3-limb add). The
    /// arithmetic is integer-exact, so the result is **bit-identical**
    /// to `scalar::binned_accumulate` — only throughput differs.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn binned_accumulate(
        limbs: &mut [i64; crate::linalg::reduce::LIMBS],
        xs: &[f64],
    ) -> u8 {
        use crate::linalg::reduce::{
            accumulate_one, add_mantissa, propagate_limbs,
        };
        let mut special = 0u8;
        let exp_mask = _mm256_set1_epi64x(0x7ff);
        let frac_mask = _mm256_set1_epi64x((1i64 << 52) - 1);
        let implicit = _mm256_set1_epi64x(1i64 << 52);
        let zero = _mm256_setzero_si256();
        for chunk in xs.chunks(super::BINNED_CHUNK) {
            let n = chunk.len();
            let p = chunk.as_ptr();
            let mut i = 0;
            while i + 4 <= n {
                let b =
                    _mm256_loadu_si256(p.add(i) as *const __m256i);
                let exp = _mm256_and_si256(
                    _mm256_srli_epi64::<52>(b),
                    exp_mask,
                );
                let frac = _mm256_and_si256(b, frac_mask);
                // Subnormal lanes (exp == 0) carry no implicit bit.
                let is_sub = _mm256_cmpeq_epi64(exp, zero);
                let mant = _mm256_or_si256(
                    frac,
                    _mm256_andnot_si256(is_sub, implicit),
                );
                let sign = _mm256_srli_epi64::<63>(b);
                let mut mant_a = [0i64; 4];
                let mut exp_a = [0i64; 4];
                let mut sign_a = [0i64; 4];
                _mm256_storeu_si256(
                    mant_a.as_mut_ptr() as *mut __m256i,
                    mant,
                );
                _mm256_storeu_si256(
                    exp_a.as_mut_ptr() as *mut __m256i,
                    exp,
                );
                _mm256_storeu_si256(
                    sign_a.as_mut_ptr() as *mut __m256i,
                    sign,
                );
                for lane in 0..4 {
                    let e = exp_a[lane];
                    let m = mant_a[lane] as u64;
                    if e == 0x7ff || m == 0 {
                        // Non-finite or ±0: the scalar slow path owns
                        // the special/zero semantics.
                        special |= accumulate_one(limbs, chunk[i + lane]);
                        continue;
                    }
                    add_mantissa(
                        limbs,
                        m,
                        (e as i32).max(1) - 1075,
                        sign_a[lane] == 1,
                    );
                }
                i += 4;
            }
            while i < n {
                special |= accumulate_one(limbs, chunk[i]);
                i += 1;
            }
            propagate_limbs(limbs);
        }
        if xs.is_empty() {
            propagate_limbs(limbs);
        }
        special
    }

    /// Row-ranged rank-1 accumulate (see `scalar::sym_rank1_upper_rows`):
    /// `block` holds rows `u0..u1` of the matrix; per-entry op order is
    /// identical regardless of the row partition. The full-matrix entry
    /// point is the dispatcher's `sym_rank1_upper`, which calls this
    /// with rows `0..d`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sym_rank1_upper_rows(
        block: &mut [f64],
        d: usize,
        u0: usize,
        u1: usize,
        samples: &[&[f64]],
        h: &[f64],
    ) {
        debug_assert_eq!(block.len(), (u1 - u0) * d);
        let mut b = 0;
        while b + 4 <= samples.len() {
            let (a0, a1, a2, a3) =
                (samples[b], samples[b + 1], samples[b + 2], samples[b + 3]);
            let (h0, h1, h2, h3) = (h[b], h[b + 1], h[b + 2], h[b + 3]);
            let (p0, p1, p2, p3) =
                (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr());
            for u in u0..u1 {
                let s0 = h0 * a0[u];
                let s1 = h1 * a1[u];
                let s2 = h2 * a2[u];
                let s3 = h3 * a3[u];
                let c0 = _mm256_set1_pd(s0);
                let c1 = _mm256_set1_pd(s1);
                let c2 = _mm256_set1_pd(s2);
                let c3 = _mm256_set1_pd(s3);
                let row = block.as_mut_ptr().add((u - u0) * d);
                let mut v = u;
                while v + 4 <= d {
                    let mut acc = _mm256_loadu_pd(row.add(v));
                    acc = _mm256_fmadd_pd(c0, _mm256_loadu_pd(p0.add(v)), acc);
                    acc = _mm256_fmadd_pd(c1, _mm256_loadu_pd(p1.add(v)), acc);
                    acc = _mm256_fmadd_pd(c2, _mm256_loadu_pd(p2.add(v)), acc);
                    acc = _mm256_fmadd_pd(c3, _mm256_loadu_pd(p3.add(v)), acc);
                    _mm256_storeu_pd(row.add(v), acc);
                    v += 4;
                }
                while v < d {
                    *row.add(v) +=
                        s0 * a0[v] + s1 * a1[v] + s2 * a2[v] + s3 * a3[v];
                    v += 1;
                }
            }
            b += 4;
        }
        while b < samples.len() {
            let a = samples[b];
            let hb = h[b];
            let pa = a.as_ptr();
            for u in u0..u1 {
                let s = hb * a[u];
                let c = _mm256_set1_pd(s);
                let row = block.as_mut_ptr().add((u - u0) * d);
                let mut v = u;
                while v + 4 <= d {
                    let acc = _mm256_fmadd_pd(
                        c,
                        _mm256_loadu_pd(pa.add(v)),
                        _mm256_loadu_pd(row.add(v)),
                    );
                    _mm256_storeu_pd(row.add(v), acc);
                    v += 4;
                }
                while v < d {
                    *row.add(v) += s * a[v];
                    v += 1;
                }
            }
            b += 1;
        }
    }
}

// Scalar-vs-dispatched equivalence properties live in
// `tests/simd_kernels.rs` (tier-1); only dispatch mechanics are unit
// tested here.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_resolves() {
        let name = isa_name();
        assert!(name == "avx2" || name == "scalar");
        // Second call hits the cache and must agree.
        assert_eq!(isa_name(), name);
    }

    #[test]
    fn gather_window_wraps() {
        let src: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut out = Vec::new();
        gather_window(&src, 7, 5, &mut out);
        assert_eq!(out, vec![7.0, 8.0, 9.0, 0.0, 1.0]);
        gather_window(&src, 0, 3, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn abs_max_ignores_nan_like_scalar() {
        // VMAXPD operand order keeps the accumulator on NaN — both
        // paths must treat NaN inputs as transparent.
        let mut x = vec![5.0, -1.0, 2.0, 3.0, f64::NAN, 0.5, -0.25, 1.0];
        x.extend(std::iter::repeat(0.1).take(9)); // force a scalar tail
        assert_eq!(abs_max(&x), 5.0);
        assert_eq!(scalar::abs_max(&x), 5.0);
    }

    #[test]
    fn triangle_row_blocks_partition_properties() {
        for (d, t) in [(1usize, 1usize), (5, 2), (37, 4), (301, 8), (8, 16)] {
            let t = t.min(d);
            let b = triangle_row_blocks(d, t);
            assert_eq!(b.len(), t + 1);
            assert_eq!(b[0], 0);
            assert_eq!(b[t], d);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
            // Deterministic in (d, t).
            assert_eq!(b, triangle_row_blocks(d, t));
        }
        // Balance: no block should carry more than ~2× the ideal
        // triangle area (coarse bound; exact balance is impossible with
        // whole rows).
        let d = 301;
        let t = 8;
        let b = triangle_row_blocks(d, t);
        let total = d * (d + 1) / 2;
        for w in b.windows(2) {
            let area: usize = (w[0]..w[1]).map(|u| d - u).sum();
            assert!(area * t <= total * 2, "block {w:?} area {area}");
        }
    }
}

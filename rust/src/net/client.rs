//! Multi-node client: connects to the master, registers its shard id,
//! then serves FedNL / FedNL-LS / FedNL-PP commands until shutdown.
//!
//! Connection establishment is interleaved with dataset loading by the
//! caller (paper §7): the caller parses its shard while the TCP connect
//! happens, then hands both to [`run_client`].

use std::net::TcpStream;

use anyhow::{Context, Result};

use super::framing::Channel;
use super::wire::{self, c2s, s2c};
use crate::algorithms::{ClientState, PPClientState};

/// Which algorithm family this client serves.
pub enum ClientMode {
    /// FedNL / FedNL-LS (Alg. 1/2 client loop).
    FedNL(ClientState),
    /// FedNL-PP (Alg. 3 client loop).
    PP(PPClientState),
}

/// Optional client-side behaviors (fault drills and tests).
#[derive(Debug, Clone, Copy, Default)]
pub struct ClientOpts {
    /// After answering this many ROUND commands, announce a graceful
    /// leave (`DEREGISTER`) and exit — simulating a departing client.
    /// The master retires the connection and, under a quorum round
    /// policy, keeps training on the survivors; this id may later
    /// rejoin by running a fresh `run_client`.
    pub leave_after_rounds: Option<u64>,
}

/// Connect to `addr`, register as `client_id`, serve until SHUTDOWN.
/// Returns (bytes_sent, bytes_received).
pub fn run_client(
    addr: &str,
    client_id: usize,
    mode: ClientMode,
) -> Result<(u64, u64)> {
    run_client_with(addr, client_id, mode, ClientOpts::default())
}

/// As [`run_client`], with explicit [`ClientOpts`].
pub fn run_client_with(
    addr: &str,
    client_id: usize,
    mut mode: ClientMode,
    opts: ClientOpts,
) -> Result<(u64, u64)> {
    let (d, family) = match &mode {
        ClientMode::FedNL(c) => (c.dim(), wire::FAMILY_FEDNL),
        ClientMode::PP(c) => (c.dim(), wire::FAMILY_PP),
    };
    let stream = connect_with_retry(addr, 50)?;
    let mut ch = Channel::new(stream)?;
    ch.send(
        c2s::REGISTER,
        &wire::encode_register(client_id as u32, d as u32, family),
    )?;

    let mut rounds_served = 0u64;
    loop {
        let (tag, payload) = ch.recv()?;
        match tag {
            s2c::ROUND => {
                // Unified round command: a FedNL client answers with
                // its Alg. 1 message, a PP client with its Alg. 3
                // participation deltas — same MSG codec either way.
                let (x, round, need_loss) = wire::decode_round(&payload)?;
                let msg = match &mut mode {
                    ClientMode::FedNL(c) => c.round(&x, round, need_loss),
                    ClientMode::PP(c) => c.participate(&x, round, need_loss),
                };
                ch.send(c2s::MSG, &wire::encode_client_msg(&msg))?;
                rounds_served += 1;
                if let Some(k) = opts.leave_after_rounds {
                    if rounds_served >= k {
                        ch.send(c2s::DEREGISTER, &[])?;
                        break;
                    }
                }
            }
            s2c::EVAL_LOSS => {
                let x = wire::decode_vec(&payload)?;
                let l = match &mut mode {
                    ClientMode::FedNL(c) => c.eval_loss(&x),
                    ClientMode::PP(c) => c.oracle.loss(&x),
                };
                ch.send(c2s::LOSS, &wire::encode_scalar(l))?;
            }
            s2c::WARM_START => {
                let x = wire::decode_vec(&payload)?;
                let packed = match &mut mode {
                    ClientMode::FedNL(c) => c.warm_start(&x),
                    _ => anyhow::bail!("WARM_START sent to a PP client"),
                };
                ch.send(c2s::WARM, &wire::encode_vec(&packed))?;
            }
            s2c::LOSS_GRAD => {
                let x = wire::decode_vec(&payload)?;
                let (l, g) = match &mut mode {
                    ClientMode::FedNL(c) => c.eval_loss_grad(&x),
                    ClientMode::PP(c) => {
                        let mut g = vec![0.0; x.len()];
                        let l = c.oracle.loss_grad(&x, &mut g);
                        (l, g)
                    }
                };
                ch.send(c2s::GRAD, &wire::encode_loss_grad(l, &g))?;
            }
            s2c::STATE => {
                let c = match &mut mode {
                    ClientMode::PP(c) => c,
                    _ => anyhow::bail!("STATE sent to a FedNL client"),
                };
                ch.send(
                    c2s::STATE,
                    &wire::encode_loss_grad(c.l_i, &c.g_i),
                )?;
            }
            s2c::SET_ALPHA => {
                let a = wire::decode_scalar(&payload)?;
                let effective = match &mut mode {
                    ClientMode::FedNL(c) => {
                        if a.is_finite() && a > 0.0 {
                            c.alpha = a;
                        }
                        c.alpha
                    }
                    ClientMode::PP(c) => {
                        if a.is_finite() && a > 0.0 {
                            c.alpha = a;
                        }
                        c.alpha
                    }
                };
                ch.send(c2s::ACK, &wire::encode_scalar(effective))?;
            }
            s2c::SHUTDOWN => break,
            other => anyhow::bail!("unknown command tag {other}"),
        }
    }
    Ok((ch.bytes_sent, ch.bytes_received))
}

/// The master may come up after the clients (Slurm-style co-scheduling;
/// same for relays connecting upward): retry the connect with backoff.
pub(crate) fn connect_with_retry(
    addr: &str,
    attempts: u32,
) -> Result<TcpStream> {
    let mut delay = std::time::Duration::from_millis(20);
    for i in 0..attempts {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(_) if i + 1 < attempts => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(std::time::Duration::from_secs(1));
            }
            Err(e) => {
                return Err(e).with_context(|| format!("connect {addr}"))
            }
        }
    }
    unreachable!()
}

//! PJRT artifact registry and the PJRT-backed logistic oracle.

use anyhow::{Context, Result};

use crate::data::ClientShard;
use crate::linalg::Mat;
use crate::oracle::Oracle;

/// One AOT-compiled shape from `artifacts/manifest.tsv`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeEntry {
    pub name: String,
    /// Problem dimension d (including intercept) the shape was built for.
    pub d_raw: usize,
    /// Max per-client samples the shape accommodates.
    pub n_raw: usize,
    pub d_pad: usize,
    pub n_pad: usize,
    pub oracle_file: String,
    pub grad_file: String,
}

/// PJRT CPU client + artifact manifest.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: String,
    pub entries: Vec<ShapeEntry>,
}

impl PjrtRuntime {
    /// Load the manifest from an artifact directory.
    pub fn load(dir: &str) -> Result<Self> {
        let manifest = std::fs::read_to_string(format!("{dir}/manifest.tsv"))
            .with_context(|| format!("reading {dir}/manifest.tsv — run `make artifacts`"))?;
        let mut entries = Vec::new();
        for line in manifest.lines() {
            let f: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(f.len() == 7, "malformed manifest line: {line}");
            entries.push(ShapeEntry {
                name: f[0].to_string(),
                d_raw: f[1].parse()?,
                n_raw: f[2].parse()?,
                d_pad: f[3].parse()?,
                n_pad: f[4].parse()?,
                oracle_file: f[5].to_string(),
                grad_file: f[6].to_string(),
            });
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir: dir.to_string(), entries })
    }

    /// Smallest artifact shape that fits a (d, n_i) client problem.
    pub fn find_shape(&self, d: usize, n_i: usize) -> Option<&ShapeEntry> {
        self.entries
            .iter()
            .filter(|e| e.d_pad >= d && e.n_pad >= n_i)
            .min_by_key(|e| (e.d_pad, e.n_pad))
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = format!("{}/{}", self.dir, file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Build a PJRT-backed oracle for one client shard.
    pub fn oracle_for_shard(
        &self,
        shard: &ClientShard,
        lam: f64,
    ) -> Result<PjrtOracle> {
        let d = shard.d();
        let n_i = shard.n_i();
        let entry = self
            .find_shape(d, n_i)
            .with_context(|| format!("no artifact fits (d={d}, n_i={n_i})"))?
            .clone();
        let exe = self.compile(&entry.oracle_file)?;
        // Pad A into (d_pad, n_pad), column j = sample j (zeros beyond).
        let (dp, np) = (entry.d_pad, entry.n_pad);
        let mut a = vec![0.0f64; dp * np];
        for s in 0..n_i {
            let row = shard.at.row(s);
            for r in 0..d {
                a[r * np + s] = row[r];
            }
        }
        // w: 1/n_i for real columns, 0 padding.
        let mut w = vec![0.0f64; np];
        for ws in w.iter_mut().take(n_i) {
            *ws = 1.0 / n_i as f64;
        }
        let a_lit =
            xla::Literal::vec1(&a).reshape(&[dp as i64, np as i64])?;
        let w_lit = xla::Literal::vec1(&w).reshape(&[np as i64])?;
        let lam_lit = xla::Literal::scalar(lam);
        // Perf note (EXPERIMENTS.md §Perf RT-1, tried & reverted):
        // keeping A/w/λ device-resident via `buffer_from_host_literal` +
        // `execute_b` would avoid re-staging ~1 MB per call, but this
        // xla_extension build cannot read tuple outputs from the buffer
        // path (`to_literal_sync` aborts on tuple-shaped buffers), and
        // the staging cost (~0.1 ms) is ≪ the 28 ms kernel anyway.
        Ok(PjrtOracle { exe, a_lit, w_lit, lam_lit, d, d_pad: dp })
    }
}

/// Logistic oracle evaluated through the AOT-compiled JAX/Pallas model.
///
/// Semantics are identical to [`crate::oracle::LogisticOracle`]
/// (cross-checked by integration tests); the compute runs in the XLA
/// executable compiled from the Pallas kernels.
pub struct PjrtOracle {
    exe: xla::PjRtLoadedExecutable,
    a_lit: xla::Literal,
    w_lit: xla::Literal,
    lam_lit: xla::Literal,
    d: usize,
    d_pad: usize,
}

// SAFETY: the PJRT CPU client is thread-safe for compilation and
// execution; the oracle is only ever used by one worker at a time
// (Oracle methods take &mut self).
unsafe impl Send for PjrtOracle {}

impl PjrtOracle {
    fn run(&self, x: &[f64]) -> (f64, Vec<f64>, Option<Mat>) {
        let mut xp = vec![0.0f64; self.d_pad];
        xp[..self.d].copy_from_slice(x);
        let x_lit = xla::Literal::vec1(&xp)
            .reshape(&[self.d_pad as i64])
            .expect("reshape x");
        let res = self
            .exe
            .execute::<xla::Literal>(&[
                self.a_lit.clone(),
                x_lit,
                self.w_lit.clone(),
                self.lam_lit.clone(),
            ])
            .expect("pjrt execute");
        // `execute` returns one tuple buffer; `execute_b` may untuple
        // into three buffers — handle both layouts.
        let (loss_l, grad_l, hess_l) = if res[0].len() == 3 {
            (
                res[0][0].to_literal_sync().expect("loss buf"),
                res[0][1].to_literal_sync().expect("grad buf"),
                res[0][2].to_literal_sync().expect("hess buf"),
            )
        } else {
            let out = res[0][0].to_literal_sync().expect("to_literal");
            out.to_tuple3().expect("oracle returns (loss, grad, hess)")
        };
        let loss = loss_l.to_vec::<f64>().expect("loss")[0];
        let grad_full = grad_l.to_vec::<f64>().expect("grad");
        let grad = grad_full[..self.d].to_vec();
        let hess_full = hess_l.to_vec::<f64>().expect("hess");
        let mut h = Mat::zeros(self.d, self.d);
        for r in 0..self.d {
            for c in 0..self.d {
                h.set(r, c, hess_full[r * self.d_pad + c]);
            }
        }
        (loss, grad, Some(h))
    }
}

impl Oracle for PjrtOracle {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        self.run(x).0
    }

    fn loss_grad(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
        let (l, grad, _) = self.run(x);
        g.copy_from_slice(&grad);
        l
    }

    fn loss_grad_hessian(
        &mut self,
        x: &[f64],
        g: &mut [f64],
        h: &mut Mat,
    ) -> f64 {
        let (l, grad, hess) = self.run(x);
        g.copy_from_slice(&grad);
        let hess = hess.unwrap();
        h.as_mut_slice().copy_from_slice(hess.as_slice());
        l
    }
}

//! The TCP shard tier: relay aggregator processes between the master
//! and its clients (`coordinator::shard`'s real-network sibling).
//!
//! Topology (paper §9.3 star, one level deeper):
//!
//! ```text
//!   master ──(S relay channels)── relay s ──(n/S client channels)── clients
//! ```
//!
//! A relay ([`run_relay`]) is a [`RemotePool`] bound to its contiguous
//! global-id partition `[base, base+count)` on the *downward* side —
//! it speaks the ordinary client-facing wire protocol, so **clients
//! cannot tell a relay from the master** — and a command-driven
//! aggregator on the *upward* side, answering the `SHARD_*` frames
//! (tag table in `net::wire`). Each round it fans the ROUND out to its
//! partition, certifies its losses, and — in the default **sum mode**
//! (the `SHARD_ROUND` `sum` flag) — folds every reply into one exact
//! [`RoundSum`] superaccumulator and forwards a single compact
//! `SHARD_SUM` frame: master fan-in drops from `n` messages of O(d)
//! each (O(n·d) payload + fold work) to `S` frames of O(d) each
//! (O(S·d)), independent of `n`, while relay-side recv/decode/fold
//! work runs in parallel across relays. Atom mode (`SHARD_MSG`, the
//! FedNL-PP path and rounds with injected straggler delays) remains
//! available behind the same flag.
//!
//! [`RelayPool`] is the master-side face: a [`ClientPool`] over the
//! whole client set, so the round engine drives a relayed deployment
//! unchanged. Determinism is inherited from the reproducible
//! summation layer (`linalg::reduce`): the merged accumulators are
//! exact, so merging S partial sums is bit-identical to folding all n
//! atoms — trajectories match the unsharded run by construction, on
//! either reply format.
//!
//! [`RoundSum`]: crate::algorithms::RoundSum
//!
//! # Recursive trees
//!
//! The tier nests: a relay started with `--parent k` serves `k` child
//! *relays* on its downward side — its downward face is a [`RelayPool`]
//! instead of a [`RemotePool`] — so S-ary trees of any depth compose
//! from the same two node kinds. Every tier pre-reduces (`SHARD_SUM`
//! merges are exact and associative), so fan-in stays O(S) per node
//! and the root's trajectory is bit-identical to the flat run on any
//! topology.
//!
//! [`RemotePool`]: super::server::RemotePool
//!
//! # Liveness through the tier
//!
//! * A relay certifies its lost clients upward (`SHARD_MSG` carries
//!   the partition's missing ids; `SHARD_PREPPED` its dead/rejoined/
//!   fresh sets from the retained downward listener).
//! * A lost **relay** (connection error, or a round reply missing the
//!   deadline-plus-slack budget) is retired and its whole partition is
//!   certified missing for the round in flight — the engine's
//!   quorum/`on_missing` policy absorbs it like any other loss. A
//!   severed relay kills its subtree abruptly (no downward SHUTDOWN),
//!   so its clients notice and **fail over**: they reconnect to a
//!   fallback address (`client --fallback`) — the master or a
//!   surviving ancestor relay — which **adopts** them: re-REGISTERed
//!   orphans are served over embedded direct channels from then on.
//!   The adopting node's `prepare_round` waits up to the adoption
//!   grace (`master --adopt-grace-ms`) for a severed partition to
//!   re-register, so the rejoin lands one round after the loss on
//!   every transport.
//! * Exactly-once application across the failover is guaranteed by the
//!   commit-ack protocol (`net::wire` § commit acks): clients that
//!   registered with `REG_WANTS_ACK` stage each round's Hᵢ shift until
//!   the master's ROUND_ACK, and a rejoiner's RESYNC watermark decides
//!   whether a stranded stage is applied (reply lost after commit) or
//!   discarded (round never committed).

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::client::connect_with_retry;
use super::framing::Channel;
use super::server::Bound;
use super::wire::{self, c2s, s2c};
use crate::algorithms::{ClientMsg, RoundSum};
use crate::coordinator::{ClientFamily, ClientPool, RoundMode};

/// Default extra patience the master grants a relay on top of the
/// per-client reply deadline: the relay must first wait out its own
/// stragglers before its SHARD_SUM / SHARD_MSG can exist. Configurable
/// per deployment via [`RelayPool::set_relay_slack`] (CLI
/// `master --relay-slack-ms`).
pub const DEFAULT_RELAY_SLACK: Duration = Duration::from_millis(2000);

/// Validate a CLI `--relay-slack-ms` value. Zero would treat every
/// relay as lost the moment a deadline is armed — "no custom slack"
/// is spelled by omitting the flag (mirroring `RoundPolicy::validate`'s
/// zero-deadline rule).
pub fn relay_slack_from_ms(ms: u64) -> Result<Duration> {
    anyhow::ensure!(
        ms > 0,
        "--relay-slack-ms 0 would certify every relay lost as soon as \
         a reply deadline is set; omit the flag for the default \
         {} ms",
        DEFAULT_RELAY_SLACK.as_millis()
    );
    Ok(Duration::from_millis(ms))
}

/// Default adoption grace: how long `prepare_round` waits for a
/// severed partition's clients to re-register directly before giving
/// them up as dead. Configurable via CLI `master --adopt-grace-ms`.
pub const DEFAULT_ADOPT_GRACE: Duration = Duration::from_millis(2000);

/// Validate a CLI `--adopt-grace-ms` value (same zero rule as
/// [`relay_slack_from_ms`]: spell "default" by omitting the flag).
pub fn adopt_grace_from_ms(ms: u64) -> Result<Duration> {
    anyhow::ensure!(
        ms > 0,
        "--adopt-grace-ms 0 would abandon every severed partition \
         before its clients could fail over; omit the flag for the \
         default {} ms",
        DEFAULT_ADOPT_GRACE.as_millis()
    );
    Ok(Duration::from_millis(ms))
}

/// One relay process' configuration (CLI `fednl relay`).
#[derive(Debug, Clone, Default)]
pub struct RelayCfg {
    /// This relay's shard id (0-based, unique per master).
    pub shard_id: u32,
    /// First global client id of the partition.
    pub base: u32,
    /// Clients in the partition.
    pub count: usize,
    /// Downward listen address for the partition's clients.
    pub listen: String,
    /// Upward master address.
    pub connect: String,
    /// Serve the downward partition through the readiness-based
    /// [`EventPool`] instead of the blocking [`RemotePool`] (CLI
    /// `relay --event`): one poll loop for the whole partition, and
    /// mux groups (`client --mux N`) can register under this relay.
    /// Unix-only; ignored (with an error at startup) elsewhere.
    ///
    /// [`EventPool`]: super::event::EventPool
    /// [`RemotePool`]: super::server::RemotePool
    pub event: bool,
    /// `Some(k)`: this node is an inner relay of a tree — its downward
    /// face is a [`RelayPool`] serving `k` child *relays* (or mux
    /// groups) whose partitions tile `[base, base+count)`, instead of
    /// `count` direct client connections (CLI `relay --parent k`).
    /// Exclusive with `event` (the inner tier has its own transports).
    pub children: Option<usize>,
    /// Scripted failover injection (CLI `relay --die-after-round R`):
    /// after fanning round `R` out to the partition — so every client
    /// computes, and stages under commit-ack — exit abruptly: no
    /// upward reply, no downward SHUTDOWN. The master certifies the
    /// partition missing for round `R` and adopts its clients at the
    /// next `prepare_round`.
    pub die_after_round: Option<u64>,
}

/// The relay's downward face: any master-side transport that can also
/// politely release its clients at end of run. Object-safe so
/// [`run_relay_on`] can pick the blocking or readiness transport at
/// startup without duplicating the serve loop.
trait DownFace: ClientPool {
    fn shutdown(&mut self);
    /// Did any downstream registrant ask for commit acks
    /// (`REG_WANTS_ACK`)? OR-folded into this node's own upward
    /// registration so SHARD_ACK traffic only flows where needed.
    fn wants_ack_any(&self) -> bool;
}

impl DownFace for super::server::RemotePool {
    fn shutdown(&mut self) {
        super::server::RemotePool::shutdown(self);
    }

    fn wants_ack_any(&self) -> bool {
        super::server::RemotePool::wants_ack_any(self)
    }
}

#[cfg(unix)]
impl DownFace for super::event::EventPool {
    fn shutdown(&mut self) {
        super::event::EventPool::shutdown(self);
    }

    fn wants_ack_any(&self) -> bool {
        super::event::EventPool::wants_ack_any(self)
    }
}

impl DownFace for RelayPool {
    fn shutdown(&mut self) {
        RelayPool::shutdown(self);
    }

    fn wants_ack_any(&self) -> bool {
        RelayPool::wants_ack_any(self)
    }
}

/// Byte totals a finished relay reports (downward pool, upward link).
#[derive(Debug, Clone, Copy, Default)]
pub struct RelayReport {
    pub down_recv: u64,
    pub down_sent: u64,
    pub up_sent: u64,
    pub up_recv: u64,
}

/// Run one relay aggregator to completion (returns after the master's
/// SHUTDOWN, which is forwarded to the partition's clients).
pub fn run_relay(cfg: &RelayCfg) -> Result<RelayReport> {
    let bound = Bound::bind(&cfg.listen)?;
    run_relay_on(bound, cfg)
}

/// As [`run_relay`] over a pre-bound downward listener (lets harnesses
/// learn the ephemeral port before spawning the partition's clients).
pub fn run_relay_on(bound: Bound, cfg: &RelayCfg) -> Result<RelayReport> {
    // Downward first: the relay must know its partition's (d, family)
    // before it can register upward.
    let mut down: Box<dyn DownFace> = if let Some(k) = cfg.children {
        // Inner node of a relay tree: the downward face is itself a
        // RelayPool over k child relays whose partitions tile this
        // node's range — every tier pre-reduces, fan-in stays O(S).
        anyhow::ensure!(
            !cfg.event,
            "--parent and --event are exclusive: the child tier \
             brings its own downward transports"
        );
        anyhow::ensure!(k > 0, "--parent needs at least one child");
        let pool = RelayPool::accept_base(bound, k, cfg.base)?;
        anyhow::ensure!(
            pool.n_clients() == cfg.count,
            "child partitions cover {} clients but this relay serves \
             {} (they must tile [base, base+count))",
            pool.n_clients(),
            cfg.count
        );
        Box::new(pool)
    } else if cfg.event {
        #[cfg(unix)]
        {
            Box::new(super::event::EventPool::accept_base(
                bound, cfg.count, cfg.base,
            )?)
        }
        #[cfg(not(unix))]
        {
            anyhow::bail!("--event requires a unix host (epoll/poll)");
        }
    } else {
        Box::new(bound.accept_base(cfg.count, cfg.base)?)
    };
    let d = down.dim();
    let family = match down.family() {
        ClientFamily::FedNL => wire::FAMILY_FEDNL,
        ClientFamily::PP => wire::FAMILY_PP,
    };
    // OR of the partition's commit-ack appetite: the parent only fans
    // SHARD_ACK frames down branches that contain staging clients, so
    // non-failover runs see zero ack bytes anywhere in the tree.
    let flags = if down.wants_ack_any() {
        wire::REG_WANTS_ACK
    } else {
        0
    };
    let stream = connect_with_retry(&cfg.connect, 50)?;
    let mut up = Channel::new(stream)?;
    up.send(
        c2s::SHARD_REGISTER,
        &wire::encode_shard_register(
            cfg.shard_id,
            cfg.base,
            cfg.count as u32,
            d as u32,
            family,
            flags,
        ),
    )?;

    loop {
        // Upward link gone (EOF or error) = this relay is severed from
        // the tree. Die abruptly — no downward SHUTDOWN — so the
        // subtree's clients observe the loss and fail over to their
        // fallback addresses. An orderly end of run is always an
        // explicit SHUTDOWN frame.
        let Ok((tag, payload)) = up.recv() else {
            break;
        };
        match tag {
            s2c::SHARD_ROUND => {
                let (x, round, need_loss, sum, deadline_ms, subset) =
                    wire::decode_shard_round(&payload)?;
                let deadline = (deadline_ms > 0)
                    .then(|| Duration::from_millis(deadline_ms));
                down.set_reply_deadline(deadline);
                down.set_round_mode(if sum {
                    RoundMode::Sums
                } else {
                    RoundMode::Atoms
                });
                down.submit_round(&x, Some(&subset), round, need_loss);
                if cfg.die_after_round == Some(round) {
                    // Scripted failover: the partition has the round
                    // (clients compute — and stage, under commit-ack).
                    // Drain their replies so every client finished its
                    // local step, then die abruptly: no upward frame,
                    // no downward SHUTDOWN. Dropping `down` severs the
                    // subtree; the parent certifies the partition
                    // missing and adoption heals it next round.
                    if sum {
                        while !down.drain_sums().is_empty() {}
                    } else {
                        while !down.drain().is_empty() {}
                    }
                    let (down_recv, down_sent) =
                        down.transport_bytes().unwrap_or((0, 0));
                    return Ok(RelayReport {
                        down_recv,
                        down_sent,
                        up_sent: up.bytes_sent,
                        up_recv: up.bytes_received,
                    });
                }
                if sum {
                    // Arithmetic pre-reduction: merge the partition's
                    // pre-reduced sums (one per sub-tier) or fold its
                    // atom replies into one exact superaccumulator —
                    // the tier's O(S·d) fan-in. Merge order is
                    // irrelevant (the sum is exact).
                    let mut merged = RoundSum::new();
                    loop {
                        let sums = down.drain_sums();
                        if sums.is_empty() {
                            break;
                        }
                        for s in sums {
                            merged.merge(s);
                        }
                    }
                    let missing = down.take_missing();
                    up.send(
                        c2s::SHARD_SUM,
                        &wire::encode_shard_sum(
                            cfg.shard_id,
                            &mut merged,
                            &missing,
                        ),
                    )?;
                } else {
                    let mut msgs: Vec<ClientMsg> = Vec::new();
                    loop {
                        let batch = down.drain();
                        if batch.is_empty() {
                            break;
                        }
                        msgs.extend(batch);
                    }
                    let mut missing = down.take_missing();
                    // Atom mode: forward the per-client batch in
                    // round-subset order. (RemotePool already surfaces
                    // replies in that order; sorting keeps the
                    // contract explicit and transport-independent.)
                    let pos = |ci: u32| {
                        subset
                            .iter()
                            .position(|&c| c == ci)
                            .expect("reply outside the round subset")
                    };
                    msgs.sort_by_key(|m| pos(m.client_id as u32));
                    missing.sort_by_key(|&c| pos(c));
                    up.send(
                        c2s::SHARD_MSG,
                        &wire::encode_shard_msg(
                            cfg.shard_id,
                            &msgs,
                            &missing,
                        ),
                    )?;
                }
            }
            s2c::SHARD_PREP => {
                let r = {
                    let mut rd = crate::utils::ByteReader::new(&payload);
                    rd.get_u64()?
                };
                down.prepare_round(r);
                let rejoined = down.take_rejoined();
                let fresh = down.take_fresh_rejoined();
                let dead = down.dead_clients();
                up.send(
                    c2s::SHARD_PREPPED,
                    &wire::encode_shard_prepped(&rejoined, &dead, &fresh),
                )?;
            }
            s2c::SHARD_ACK => {
                // Commit fan-out: the parent committed `round` with
                // these partition ids counted — forward so staging
                // clients apply their staged Hᵢ shift. No reply (acks
                // ride ahead of the next ROUND on the same FIFO).
                let (round, ids) = wire::decode_shard_ack(&payload)?;
                down.ack_round(round, &ids);
            }
            s2c::RESYNC => {
                // Rejoin watermark for one client of the partition:
                // route it down the tier (the leaf pool emits the
                // client-facing 9-byte RESYNC).
                let (client, lc) = wire::decode_shard_resync(&payload)?;
                down.resolve_staged(client, lc);
            }
            s2c::PULL_H => {
                // Exact Hᵢ resync pull: batch the partition's packed
                // Hessians upward (empty batch = partition incomplete,
                // the root falls back to the approximate resync).
                let packs = down.pull_h_packed().unwrap_or_default();
                up.send(c2s::SHARD_WARM, &wire::encode_vec_batch(&packs))?;
            }
            s2c::SHARD_PULL => {
                let client = {
                    let mut rd = crate::utils::ByteReader::new(&payload);
                    rd.get_u32()?
                };
                let state = down.pull_state(client);
                up.send(
                    c2s::SHARD_PULLED,
                    &wire::encode_shard_pulled(
                        state.as_ref().map(|(l, g)| (*l, g.as_slice())),
                    ),
                )?;
            }
            s2c::EVAL_LOSS => {
                let x = wire::decode_vec(&payload)?;
                let parts = down.eval_loss_each(&x);
                up.send(c2s::SHARD_LOSSES, &wire::encode_id_scalars(&parts))?;
            }
            s2c::LOSS_GRAD => {
                let x = wire::decode_vec(&payload)?;
                let parts = down.loss_grad_each(&x);
                up.send(
                    c2s::SHARD_GRADS,
                    &wire::encode_id_scalar_vecs(&parts),
                )?;
            }
            s2c::LOSS_GRAD_SUM => {
                // Pre-reduced probe: fold the partition's (fᵢ, ∇fᵢ)
                // next to the clients and ship one exact accumulator
                // pair — O(d) upward instead of n dense gradients.
                let x = wire::decode_vec(&payload)?;
                let (mut loss, mut grad, count) = down.loss_grad_sum(&x);
                up.send(
                    c2s::SHARD_GRAD_SUM,
                    &wire::encode_shard_grad_sum(
                        count, &mut loss, &mut grad,
                    ),
                )?;
            }
            s2c::WARM_START => {
                let x = wire::decode_vec(&payload)?;
                let packs = down.warm_start(&x);
                up.send(c2s::SHARD_WARM, &wire::encode_vec_batch(&packs))?;
            }
            s2c::STATE => {
                let states = down.init_state();
                let parts: Vec<(u32, f64, Vec<f64>)> = states
                    .into_iter()
                    .enumerate()
                    .map(|(slot, (l, g))| {
                        (cfg.base + slot as u32, l, g)
                    })
                    .collect();
                up.send(
                    c2s::SHARD_STATES,
                    &wire::encode_id_scalar_vecs(&parts),
                )?;
            }
            s2c::SET_ALPHA => {
                // Forward the negotiation (finite = install, NaN =
                // query) and echo the partition's effective α upward.
                let a = wire::decode_scalar(&payload)?;
                let effective = down.set_alpha(a);
                up.send(c2s::ACK, &wire::encode_scalar(effective))?;
            }
            s2c::SHUTDOWN => {
                down.shutdown();
                break;
            }
            other => anyhow::bail!("relay: unknown command tag {other}"),
        }
    }
    let (down_recv, down_sent) = down.transport_bytes().unwrap_or((0, 0));
    Ok(RelayReport {
        down_recv,
        down_sent,
        up_sent: up.bytes_sent,
        up_recv: up.bytes_received,
    })
}

/// One failed-over client served directly by the adopting node after
/// its relay died (the "embedded RemotePool slot" of the adoption
/// path).
struct Adopted {
    id: u32,
    ch: Channel,
    /// Registered with `REG_WANTS_ACK` (it did, if it failed over —
    /// tracked anyway so ack gating stays uniform).
    wants_ack: bool,
}

/// Master-side handle to `S` relay aggregators, presented as one
/// [`ClientPool`] over the whole client set. Doubles as the downward
/// face of an inner tree node (`relay --parent`), where the "client
/// set" is that node's contiguous sub-partition.
pub struct RelayPool {
    /// Upward channels indexed by shard id (`None` = lost relay).
    relays: Vec<Option<Channel>>,
    /// Global-id range `[lo, hi)` per shard (contiguous, ascending
    /// from `base`).
    ranges: Vec<(u32, u32)>,
    /// First global id served (0 at the root; an inner tree node
    /// serves its own partition).
    base: u32,
    n_clients: usize,
    d: usize,
    family: ClientFamily,
    alpha: f64,
    /// Kept open after registration so a severed partition's clients
    /// can fail over here; polled (non-blocking) in `prepare_round`.
    listener: Option<TcpListener>,
    /// Shards with an outstanding SHARD_MSG, ascending shard id.
    pending: VecDeque<u32>,
    /// Adopted clients with an outstanding ROUND reply, subset order.
    adopted_pending: VecDeque<u32>,
    /// Participants of the round in flight, per shard (cleared once
    /// the shard's batch arrives; a relay lost mid-round certifies the
    /// remainder).
    outstanding: Vec<Vec<u32>>,
    missing: Vec<u32>,
    rejoined: Vec<u32>,
    /// Rejoiners that re-registered with `REG_FRESH` (blank Hᵢ) since
    /// the last take — the engine's exact-resync trigger.
    fresh: Vec<u32>,
    /// Dead clients per live shard, from the last SHARD_PREPPED poll.
    shard_dead: Vec<Vec<u32>>,
    /// `REG_WANTS_ACK` per shard, from registration: SHARD_ACK frames
    /// only flow down branches that asked for them.
    shard_ack: Vec<bool>,
    /// Failed-over clients served directly (their relay died).
    adopted: Vec<Adopted>,
    /// Ids severed with their relay, awaiting direct re-registration:
    /// the next `prepare_round` blocks up to `adopt_grace` for them.
    orphans: Vec<u32>,
    /// Orphans the grace expired on: reported dead, admitted if they
    /// ever do come back, never waited for again.
    abandoned: Vec<u32>,
    deadline: Option<Duration>,
    /// Forwarding patience on top of `deadline` (see
    /// [`DEFAULT_RELAY_SLACK`]; CLI `master --relay-slack-ms`).
    slack: Duration,
    /// How long `prepare_round` waits for a severed partition to fail
    /// over (see [`DEFAULT_ADOPT_GRACE`]; CLI `master
    /// --adopt-grace-ms`).
    adopt_grace: Duration,
    /// Reply format requested from the relays for subsequent rounds
    /// (encoded into each SHARD_ROUND frame at submit time).
    mode: RoundMode,
    retired_bytes: (u64, u64),
}

impl RelayPool {
    /// Listen on `addr` until exactly `n_shards` relays register; the
    /// partitions must tile `0..n` contiguously.
    pub fn listen(addr: &str, n_shards: usize) -> Result<Self> {
        Self::accept(Bound::bind(addr)?, n_shards)
    }

    /// Accept `n_shards` relay registrations on a pre-bound socket.
    pub fn accept(bound: Bound, n_shards: usize) -> Result<Self> {
        Self::accept_base(bound, n_shards, 0)
    }

    /// As [`RelayPool::accept`] for the global-id partition starting
    /// at `base` — the downward face of an inner tree node, whose
    /// children tile `[base, base+n)` instead of `[0, n)`.
    pub fn accept_base(
        bound: Bound,
        n_shards: usize,
        pool_base: u32,
    ) -> Result<Self> {
        let listener = bound.into_listener();
        let mut relays: Vec<Option<Channel>> =
            (0..n_shards).map(|_| None).collect();
        let mut ranges: Vec<Option<(u32, u32)>> = vec![None; n_shards];
        let mut acks = vec![false; n_shards];
        let mut d = 0u32;
        let mut family = None;
        let mut registered = 0;
        while registered < n_shards {
            let (stream, _) = listener.accept()?;
            let mut ch = Channel::new(stream)?;
            let (tag, payload) = ch.recv()?;
            anyhow::ensure!(
                tag == c2s::SHARD_REGISTER,
                "expected SHARD_REGISTER"
            );
            let (sid, base, count, dim, fam, flags) =
                wire::decode_shard_register(&payload)?;
            let sid = sid as usize;
            anyhow::ensure!(sid < n_shards, "shard id {sid} out of range");
            anyhow::ensure!(relays[sid].is_none(), "duplicate shard {sid}");
            if d == 0 {
                d = dim;
            } else {
                anyhow::ensure!(d == dim, "shard dimension mismatch");
            }
            let f = match fam {
                wire::FAMILY_FEDNL => ClientFamily::FedNL,
                _ => ClientFamily::PP,
            };
            match family {
                None => family = Some(f),
                Some(prev) => anyhow::ensure!(
                    prev == f,
                    "shard {sid} registered as {f:?} but earlier shards \
                     as {prev:?}: the tier is family-homogeneous"
                ),
            }
            relays[sid] = Some(ch);
            ranges[sid] = Some((base, base + count));
            acks[sid] = flags & wire::REG_WANTS_ACK != 0;
            registered += 1;
        }
        let ranges: Vec<(u32, u32)> =
            ranges.into_iter().map(|r| r.unwrap()).collect();
        let mut expect = pool_base;
        for (s, &(lo, hi)) in ranges.iter().enumerate() {
            anyhow::ensure!(
                lo == expect,
                "shard {s} partition starts at {lo}, expected {expect}: \
                 partitions must tile the pool's range contiguously in \
                 shard order"
            );
            expect = hi;
        }
        // Keep listening so a severed partition can fail over here;
        // polled non-blocking between rounds.
        listener
            .set_nonblocking(true)
            .context("set_nonblocking on retained relay listener")?;
        let n_shards_len = relays.len();
        Ok(Self {
            relays,
            ranges,
            base: pool_base,
            n_clients: (expect - pool_base) as usize,
            d: d as usize,
            family: family.context("no shards registered")?,
            alpha: 0.0,
            listener: Some(listener),
            pending: VecDeque::new(),
            adopted_pending: VecDeque::new(),
            outstanding: vec![Vec::new(); n_shards_len],
            missing: Vec::new(),
            rejoined: Vec::new(),
            fresh: Vec::new(),
            shard_dead: vec![Vec::new(); n_shards_len],
            shard_ack: acks,
            adopted: Vec::new(),
            orphans: Vec::new(),
            abandoned: Vec::new(),
            deadline: None,
            slack: DEFAULT_RELAY_SLACK,
            adopt_grace: DEFAULT_ADOPT_GRACE,
            mode: RoundMode::Atoms,
            retired_bytes: (0, 0),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.relays.len()
    }

    /// Configure the relay forwarding slack (the extra patience on top
    /// of the per-client reply deadline before a silent relay is
    /// certified lost). CLI: `master --relay-slack-ms`.
    pub fn set_relay_slack(&mut self, slack: Duration) {
        self.slack = slack.max(Duration::from_millis(1));
    }

    /// Configure the adoption grace (how long `prepare_round` waits
    /// for a severed partition's clients to fail over before they are
    /// abandoned as dead). CLI: `master --adopt-grace-ms`.
    pub fn set_adopt_grace(&mut self, grace: Duration) {
        self.adopt_grace = grace.max(Duration::from_millis(1));
    }

    /// Did any registrant of this tier ask for commit acks?
    pub fn wants_ack_any(&self) -> bool {
        self.shard_ack.iter().any(|&a| a)
            || self.adopted.iter().any(|a| a.wants_ack)
    }

    /// Retire a relay: fold its byte meters, certify the round
    /// participants it still owed, and orphan its partition — the ids
    /// are reported dead until (and unless) their clients fail over
    /// to this node's retained listener and are adopted.
    fn drop_relay(&mut self, s: usize) {
        if let Some(ch) = self.relays[s].take() {
            self.retired_bytes.0 += ch.bytes_received;
            self.retired_bytes.1 += ch.bytes_sent;
            // First severance of this shard: every id not already
            // served directly becomes an orphan the next
            // prepare_round waits for — except ids the relay itself
            // reported dead, which have nobody left to fail over
            // (they are abandoned immediately, though still admitted
            // if they ever reconnect).
            let (lo, hi) = self.ranges[s];
            for c in lo..hi {
                if self.adopted.iter().any(|a| a.id == c) {
                    continue;
                }
                if self.shard_dead[s].contains(&c) {
                    self.abandoned.push(c);
                } else {
                    self.orphans.push(c);
                }
            }
        }
        self.missing.append(&mut self.outstanding[s]);
        self.shard_dead[s].clear();
    }

    /// Retire one adopted client's channel (folding its byte meters);
    /// the id may fail over again later.
    fn retire_adopted(&mut self, id: u32) {
        if let Some(pos) = self.adopted.iter().position(|a| a.id == id) {
            let a = self.adopted.swap_remove(pos);
            self.retired_bytes.0 += a.ch.bytes_received;
            self.retired_bytes.1 += a.ch.bytes_sent;
            self.abandoned.push(id);
        }
    }

    fn adopted_mut(&mut self, id: u32) -> Option<&mut Adopted> {
        self.adopted.iter_mut().find(|a| a.id == id)
    }

    /// Non-blocking accept sweep: admit any orphaned (or abandoned)
    /// id re-registering directly. Returns how many were adopted.
    fn poll_adoptions(&mut self) -> usize {
        let mut admitted = 0;
        // Cap accepts per sweep so a reconnect-looping peer cannot
        // stall `prepare_round` (mirrors RemotePool::poll_rejoins).
        for _ in 0..self.n_clients.max(1) {
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return admitted,
            };
            match accepted {
                Ok((stream, _)) => {
                    if self.admit_adoption(stream).is_some() {
                        admitted += 1;
                    }
                }
                Err(_) => break, // WouldBlock (or transient): done
            }
        }
        admitted
    }

    /// Validate one failed-over client; returns its global id if
    /// adopted. A malformed or conflicting registration drops the
    /// connection (same non-panicking rule as every network input).
    fn admit_adoption(&mut self, stream: TcpStream) -> Option<u32> {
        stream.set_nonblocking(false).ok()?;
        let handshake = self.deadline.unwrap_or(Duration::from_secs(1));
        stream.set_read_timeout(Some(handshake)).ok()?;
        let mut ch = Channel::new(stream).ok()?;
        let (tag, payload) = ch.recv().ok()?;
        if tag != c2s::REGISTER {
            return None;
        }
        let (id, dim, family, flags) =
            wire::decode_register(&payload).ok()?;
        let family = match family {
            wire::FAMILY_FEDNL => ClientFamily::FedNL,
            _ => ClientFamily::PP,
        };
        let orphaned = self.orphans.contains(&id)
            || self.abandoned.contains(&id);
        let admissible = orphaned
            && dim as usize == self.d
            && family == self.family
            && self.adopted.iter().all(|a| a.id != id);
        if !admissible {
            return None;
        }
        // Resync the Hessian learning rate, exactly like a flat-master
        // rejoin (`RemotePool::admit_rejoin`): the adopted client must
        // train under the α this node aggregates with.
        if self.alpha > 0.0 {
            let sent = ch
                .send(s2c::SET_ALPHA, &wire::encode_scalar(self.alpha))
                .is_ok();
            let acked = sent
                && matches!(ch.recv(), Ok((tag, _)) if tag == c2s::ACK);
            if !acked {
                return None;
            }
        }
        self.orphans.retain(|&c| c != id);
        self.abandoned.retain(|&c| c != id);
        self.adopted.push(Adopted {
            id,
            ch,
            wants_ack: flags & wire::REG_WANTS_ACK != 0,
        });
        self.rejoined.push(id);
        if flags & wire::REG_FRESH != 0 {
            self.fresh.push(id);
        }
        Some(id)
    }

    /// The adoption barrier: if a partition was severed since the
    /// last round, block up to `adopt_grace` for its clients to fail
    /// over; whoever misses the grace is abandoned (reported dead, no
    /// further waiting). With no fresh orphans this is one
    /// non-blocking sweep.
    fn adopt_orphans(&mut self) {
        if self.orphans.is_empty() {
            self.poll_adoptions();
            return;
        }
        let deadline = Instant::now() + self.adopt_grace;
        while !self.orphans.is_empty() && Instant::now() < deadline {
            if self.poll_adoptions() == 0 {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        self.abandoned.append(&mut self.orphans);
    }

    /// Send one command to every live relay; returns the shard ids
    /// actually sent (send failures drop the relay).
    fn ask_relays(&mut self, tag: u8, payload: &[u8]) -> Vec<usize> {
        let mut asked = Vec::with_capacity(self.relays.len());
        for s in 0..self.relays.len() {
            if let Some(ch) = self.relays[s].as_mut() {
                match ch.send(tag, payload) {
                    Ok(()) => asked.push(s),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        asked
    }

    /// Blocking receive of one probe reply from shard `s` (unbounded,
    /// like `RemotePool`'s probe receives — WARM_START legitimately
    /// exceeds round deadlines). Failures drop the relay and return
    /// `None` so the reduction proceeds over the surviving partitions.
    fn recv_expect(&mut self, s: usize, want: u8) -> Option<Vec<u8>> {
        self.recv_expect_within(s, want, None)
    }

    /// Receive one adopted client's round reply (deadline-bounded).
    /// Returns the message plus its framed byte size; failures retire
    /// the client and certify it missing.
    fn recv_adopted_msg(&mut self, ci: u32) -> Option<(ClientMsg, u64)> {
        let deadline = self.deadline;
        let Some(a) = self.adopted_mut(ci) else {
            self.missing.push(ci);
            return None;
        };
        let _ = a.ch.set_read_timeout(deadline);
        if let Ok((tag, p)) = a.ch.recv() {
            if tag == c2s::MSG {
                if let Ok(m) = wire::decode_client_msg(&p) {
                    if m.client_id == ci as usize {
                        let bytes = crate::net::FRAME_HEADER_BYTES
                            + p.len() as u64;
                        return Some((m, bytes));
                    }
                }
            }
        }
        // Deadline missed, connection died, or a protocol violation:
        // retire and certify (never a panic — network-facing input).
        self.retire_adopted(ci);
        self.missing.push(ci);
        None
    }

    /// Send one probe command to every adopted client; returns the ids
    /// actually sent (send failures retire).
    fn ask_adopted(&mut self, tag: u8, payload: &[u8]) -> Vec<u32> {
        let ids: Vec<u32> = self.adopted.iter().map(|a| a.id).collect();
        let mut asked = Vec::with_capacity(ids.len());
        for id in ids {
            let Some(a) = self.adopted_mut(id) else { continue };
            match a.ch.send(tag, payload) {
                Ok(()) => asked.push(id),
                Err(_) => self.retire_adopted(id),
            }
        }
        asked
    }

    /// Blocking receive of one probe reply from adopted client `ci`
    /// (unbounded, mirroring [`RelayPool::recv_expect`]).
    fn recv_adopted_expect(&mut self, ci: u32, want: u8) -> Option<Vec<u8>> {
        let a = self.adopted_mut(ci)?;
        let _ = a.ch.set_read_timeout(None);
        match a.ch.recv() {
            Ok((tag, payload)) if tag == want => Some(payload),
            _ => {
                self.retire_adopted(ci);
                None
            }
        }
    }

    /// As [`RelayPool::recv_expect`] with an explicit receive budget —
    /// the per-round exchanges (SHARD_PREP) use `deadline + slack` so
    /// a hung-but-connected relay is certified lost instead of
    /// stalling the run the quorum policy is protecting.
    fn recv_expect_within(
        &mut self,
        s: usize,
        want: u8,
        timeout: Option<Duration>,
    ) -> Option<Vec<u8>> {
        let ch = self.relays[s].as_mut()?;
        let _ = ch.set_read_timeout(timeout);
        match ch.recv() {
            Ok((tag, payload)) if tag == want => Some(payload),
            _ => {
                self.drop_relay(s);
                None
            }
        }
    }

    /// Politely shut the tier down (relays forward to their clients;
    /// adopted clients are released directly).
    pub fn shutdown(&mut self) {
        for ch in self.relays.iter_mut().flatten() {
            let _ = ch.send(s2c::SHUTDOWN, &[]);
        }
        for a in &mut self.adopted {
            let _ = a.ch.send(s2c::SHUTDOWN, &[]);
        }
    }
}

impl ClientPool for RelayPool {
    fn n_clients(&self) -> usize {
        self.n_clients
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn family(&self) -> ClientFamily {
        self.family
    }

    fn kind_name(&self) -> &'static str {
        "relay"
    }

    fn default_alpha(&self) -> f64 {
        // NaN = "ask the tier": the SET_ALPHA negotiation cascades
        // through the relays to the clients (see `RemotePool`).
        if self.alpha > 0.0 {
            self.alpha
        } else {
            f64::NAN
        }
    }

    fn set_alpha(&mut self, alpha: f64) -> f64 {
        let payload = wire::encode_scalar(alpha);
        let asked = self.ask_relays(s2c::SET_ALPHA, &payload);
        let mut echoes = Vec::with_capacity(asked.len());
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::ACK) {
                if let Ok(a) = wire::decode_scalar(&p) {
                    echoes.push(a);
                }
            }
        }
        let (resolved, homogeneous) =
            wire::fold_alpha_echoes(alpha, echoes);
        // Mixed per-shard echoes: install the resolved α uniformly so
        // every partition trains with the α the master aggregates with
        // (mirrors RemotePool::set_alpha; no-op when homogeneous).
        if !homogeneous && resolved.is_finite() && resolved > 0.0 {
            let payload = wire::encode_scalar(resolved);
            let asked = self.ask_relays(s2c::SET_ALPHA, &payload);
            for s in asked {
                let _ = self.recv_expect(s, c2s::ACK);
            }
        }
        self.alpha = resolved;
        resolved
    }

    fn set_reply_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline.map(|d| d.max(Duration::from_millis(1)));
    }

    fn prepare_round(&mut self, round: u64) {
        // A relay that died since the last exchange (EOF on its
        // channel) is certified *before* this round is dispatched —
        // the silent-partition fix: quorum math sees the loss in the
        // same round on every transport, instead of a zero-reply
        // round that only surfaces at drain time.
        for s in 0..self.relays.len() {
            let dead = self.relays[s]
                .as_ref()
                .is_some_and(|ch| ch.peek_eof());
            if dead {
                self.drop_relay(s);
            }
        }
        let dead_adopted: Vec<u32> = self
            .adopted
            .iter()
            .filter(|a| a.ch.peek_eof())
            .map(|a| a.id)
            .collect();
        for id in dead_adopted {
            self.retire_adopted(id);
        }
        // Adoption barrier: freshly severed partitions get one grace
        // window to fail over before they are abandoned as dead.
        self.adopt_orphans();
        // One liveness poll per relay per round: rejoins admitted by
        // the relays' retained listeners surface here, and the dead
        // sets feed the PP resampling policy.
        let payload = {
            let mut w = crate::utils::ByteWriter::with_capacity(8);
            w.put_u64(round);
            w.into_vec()
        };
        let asked = self.ask_relays(s2c::SHARD_PREP, &payload);
        // Bounded per-round exchange: with a reply deadline configured
        // a wedged relay must become a certified loss here, not a
        // master hang (the flat master's prepare_round is non-blocking
        // for the same reason). The budget covers a child's own
        // adoption barrier, which runs inside its SHARD_PREP handling.
        let budget =
            self.deadline.map(|d| d + self.slack + self.adopt_grace);
        for s in asked {
            match self.recv_expect_within(s, c2s::SHARD_PREPPED, budget) {
                Some(p) => match wire::decode_shard_prepped(&p) {
                    Ok((rejoined, dead, fresh)) => {
                        self.rejoined.extend(rejoined);
                        self.fresh.extend(fresh);
                        self.shard_dead[s] = dead;
                    }
                    Err(_) => self.drop_relay(s),
                },
                None => {}
            }
        }
    }

    fn dead_clients(&self) -> Vec<u32> {
        // Live relays report their partitions' dead sets; a severed
        // partition's ids are dead while orphaned or abandoned (an
        // adopted id is alive again and appears in neither list).
        let mut out = Vec::new();
        for s in 0..self.relays.len() {
            if self.relays[s].is_some() {
                out.extend(self.shard_dead[s].iter().copied());
            }
        }
        out.extend(self.orphans.iter().copied());
        out.extend(self.abandoned.iter().copied());
        out.sort_unstable();
        out
    }

    fn take_missing(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.missing)
    }

    fn take_rejoined(&mut self) -> Vec<u32> {
        let mut out = std::mem::take(&mut self.rejoined);
        out.sort_unstable();
        out
    }

    fn take_fresh_rejoined(&mut self) -> Vec<u32> {
        let mut out = std::mem::take(&mut self.fresh);
        out.sort_unstable();
        out
    }

    fn submit_round(
        &mut self,
        x: &[f64],
        subset: Option<&[u32]>,
        round: u64,
        need_loss: bool,
    ) {
        assert!(self.pending.is_empty(), "previous round not fully drained");
        assert!(
            self.adopted_pending.is_empty(),
            "previous round not fully drained"
        );
        let deadline_ms =
            self.deadline.map(|d| d.as_millis() as u64).unwrap_or(0);
        let round_payload = wire::encode_round(x, round, need_loss);
        for s in 0..self.relays.len() {
            let (lo, hi) = self.ranges[s];
            let part: Vec<u32> = match subset {
                None => (lo..hi).collect(),
                Some(sub) => sub
                    .iter()
                    .copied()
                    .filter(|&c| c >= lo && c < hi)
                    .collect(),
            };
            if part.is_empty() {
                continue;
            }
            if self.relays[s].is_none() {
                // Severed partition: adopted participants are served
                // over their direct channels (the flat client
                // protocol); the rest are certified missing.
                for ci in part {
                    let Some(a) = self.adopted_mut(ci) else {
                        self.missing.push(ci);
                        continue;
                    };
                    match a.ch.send(s2c::ROUND, &round_payload) {
                        Ok(()) => self.adopted_pending.push_back(ci),
                        Err(_) => {
                            self.retire_adopted(ci);
                            self.missing.push(ci);
                        }
                    }
                }
                continue;
            }
            let payload = wire::encode_shard_round(
                x,
                round,
                need_loss,
                self.mode == RoundMode::Sums,
                deadline_ms,
                &part,
            );
            let ch = self.relays[s].as_mut().unwrap();
            match ch.send(s2c::SHARD_ROUND, &payload) {
                Ok(()) => {
                    self.outstanding[s] = part;
                    self.pending.push_back(s as u32);
                }
                Err(_) => {
                    self.outstanding[s] = part;
                    self.drop_relay(s);
                }
            }
        }
    }

    fn set_round_mode(&mut self, mode: RoundMode) {
        self.mode = mode;
    }

    fn drain_sums(&mut self) -> Vec<RoundSum> {
        // Sum mode: one pre-reduced SHARD_SUM per relay per round,
        // ascending shard id — O(S·d) master fan-in. Validation is
        // count-based (committed + missing must tile the partition we
        // dispatched); a malformed or inconsistent frame retires the
        // relay and certifies its outstanding partition, never a
        // panic (network-facing input rule).
        debug_assert_eq!(self.mode, RoundMode::Sums);
        while let Some(s) = self.pending.pop_front() {
            let s = s as usize;
            let Some(ch) = self.relays[s].as_mut() else {
                self.missing.append(&mut self.outstanding[s]);
                continue;
            };
            let timeout = self.deadline.map(|d| d + self.slack);
            let _ = ch.set_read_timeout(timeout);
            match ch.recv() {
                Ok((tag, p)) if tag == c2s::SHARD_SUM => {
                    let Ok((sid, mut sum, missing)) =
                        wire::decode_shard_sum(&p, self.d)
                    else {
                        self.drop_relay(s);
                        continue;
                    };
                    let part = &self.outstanding[s];
                    let mut miss_sorted = missing.clone();
                    miss_sorted.sort_unstable();
                    let dups =
                        miss_sorted.windows(2).any(|w| w[0] == w[1]);
                    let valid = sid as usize == s
                        && !dups
                        && sum.committed as usize + missing.len()
                            == part.len()
                        && missing.iter().all(|c| part.contains(c));
                    if !valid {
                        self.drop_relay(s);
                        continue;
                    }
                    self.outstanding[s].clear();
                    self.missing.extend(missing);
                    if sum.committed == 0 {
                        continue; // whole partition certified
                    }
                    sum.wire_bytes = crate::net::FRAME_HEADER_BYTES
                        + p.len() as u64;
                    return vec![sum];
                }
                _ => self.drop_relay(s),
            }
        }
        // Adopted clients answer with flat atom replies; fold them
        // into one exact accumulator (order-irrelevant: the merge is
        // exact, so the healed topology stays bit-identical).
        if !self.adopted_pending.is_empty() {
            let mut merged = RoundSum::new();
            let mut bytes = 0u64;
            while let Some(ci) = self.adopted_pending.pop_front() {
                if let Some((m, b)) = self.recv_adopted_msg(ci) {
                    merged.absorb(&m);
                    bytes += b;
                }
            }
            if merged.committed > 0 {
                merged.wire_bytes = bytes;
                return vec![merged];
            }
        }
        Vec::new()
    }

    fn drain(&mut self) -> Vec<ClientMsg> {
        // One SHARD_MSG per call, ascending shard id: while the master
        // commits shard s's batch, the later relays' frames queue in
        // the OS socket buffers. A relay that cannot produce its frame
        // within deadline + slack (or whose connection dies) certifies
        // its whole outstanding partition.
        debug_assert_eq!(self.mode, RoundMode::Atoms);
        while let Some(s) = self.pending.pop_front() {
            let s = s as usize;
            let Some(ch) = self.relays[s].as_mut() else {
                self.missing.append(&mut self.outstanding[s]);
                continue;
            };
            let timeout = self.deadline.map(|d| d + self.slack);
            let _ = ch.set_read_timeout(timeout);
            match ch.recv() {
                Ok((tag, p)) if tag == c2s::SHARD_MSG => {
                    // Network-facing input: a malformed or inconsistent
                    // frame retires the relay (certifying its whole
                    // outstanding partition) — never a panic, exactly
                    // like `RemotePool::drain` treats a bad client.
                    let Ok((sid, msgs, mut missing)) =
                        wire::decode_shard_msg(&p)
                    else {
                        self.drop_relay(s);
                        continue;
                    };
                    // Every id the relay accounts for must be one of
                    // the participants we handed it, exactly once.
                    // (Cloned so the failure paths below can mutate
                    // the pool; partitions are O(n/S) ids.)
                    let part = self.outstanding[s].clone();
                    let mut accounted: Vec<u32> = msgs
                        .iter()
                        .map(|m| m.client_id as u32)
                        .chain(missing.iter().copied())
                        .collect();
                    accounted.sort_unstable();
                    let dups =
                        accounted.windows(2).any(|w| w[0] == w[1]);
                    let valid = sid as usize == s
                        && !dups
                        && accounted.iter().all(|c| part.contains(c));
                    if !valid {
                        self.drop_relay(s);
                        continue;
                    }
                    // A participant the relay left unaccounted (it
                    // must not: its downward pool certifies losses)
                    // would hang the round engine — certify it here.
                    for &c in &part {
                        if !accounted.contains(&c) {
                            missing.push(c);
                        }
                    }
                    self.outstanding[s].clear();
                    self.missing.extend(missing);
                    if msgs.is_empty() {
                        continue; // whole partition was certified
                    }
                    return msgs;
                }
                _ => self.drop_relay(s),
            }
        }
        // Adopted clients reply one atom each, in subset order.
        while let Some(ci) = self.adopted_pending.pop_front() {
            if let Some((m, _)) = self.recv_adopted_msg(ci) {
                return vec![m];
            }
        }
        Vec::new()
    }

    fn eval_loss_each(&mut self, x: &[f64]) -> Vec<(u32, f64)> {
        // Probe replies are network-facing input: a malformed batch
        // retires the relay and the reduction proceeds over the
        // surviving partitions (same rule as `drain`).
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::EVAL_LOSS, &payload);
        let adopted = self.ask_adopted(s2c::EVAL_LOSS, &payload);
        let mut parts = Vec::with_capacity(self.n_clients);
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_LOSSES) {
                match wire::decode_id_scalars(&p) {
                    Ok(batch) => parts.extend(batch),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        for ci in adopted {
            if let Some(p) = self.recv_adopted_expect(ci, c2s::LOSS) {
                match wire::decode_scalar(&p) {
                    Ok(l) => parts.push((ci, l)),
                    Err(_) => self.retire_adopted(ci),
                }
            }
        }
        parts
    }

    fn loss_grad_each(&mut self, x: &[f64]) -> Vec<(u32, f64, Vec<f64>)> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::LOSS_GRAD, &payload);
        let adopted = self.ask_adopted(s2c::LOSS_GRAD, &payload);
        let mut parts = Vec::with_capacity(self.n_clients);
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_GRADS) {
                match wire::decode_id_scalar_vecs(&p) {
                    Ok(batch) => parts.extend(batch),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        for ci in adopted {
            if let Some(p) = self.recv_adopted_expect(ci, c2s::GRAD) {
                match wire::decode_loss_grad(&p) {
                    Ok((l, g)) => parts.push((ci, l, g)),
                    Err(_) => self.retire_adopted(ci),
                }
            }
        }
        parts
    }

    fn loss_grad_sum(
        &mut self,
        x: &[f64],
    ) -> (
        crate::linalg::reduce::RepAcc,
        crate::linalg::reduce::RepVec,
        u32,
    ) {
        // Pre-reduced probe over the tier: one SHARD_GRAD_SUM frame
        // per relay (O(S·d) fan-in) merged exactly — bit-identical to
        // the flat atom fold. A malformed reply retires the relay and
        // the reduction proceeds over the surviving partitions (same
        // rule as the other probes).
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::LOSS_GRAD_SUM, &payload);
        let adopted = self.ask_adopted(s2c::LOSS_GRAD, &payload);
        let mut loss = crate::linalg::reduce::RepAcc::new();
        let mut grad = crate::linalg::reduce::RepVec::new(self.d);
        let mut count = 0u32;
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_GRAD_SUM) {
                match wire::decode_shard_grad_sum(&p, self.d) {
                    // A short gradient accumulator is as malformed as
                    // an undecodable one (merge requires length d).
                    Ok((c, l, g)) if g.len() == self.d => {
                        loss.merge(l);
                        grad.merge(g);
                        count += c;
                    }
                    _ => self.drop_relay(s),
                }
            }
        }
        // Adopted atoms accumulate into the same exact reduction the
        // flat pools use — grouping-invariant, so the healed topology
        // probes bit-identically.
        for ci in adopted {
            if let Some(p) = self.recv_adopted_expect(ci, c2s::GRAD) {
                match wire::decode_loss_grad(&p) {
                    Ok((l, g)) if g.len() == self.d => {
                        loss.accumulate(l);
                        grad.accumulate(&g);
                        count += 1;
                    }
                    _ => self.retire_adopted(ci),
                }
            }
        }
        (loss, grad, count)
    }

    fn warm_start(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        let payload = wire::encode_vec(x);
        let asked = self.ask_relays(s2c::WARM_START, &payload);
        let mut packs = Vec::with_capacity(self.n_clients);
        for s in asked {
            if let Some(p) = self.recv_expect(s, c2s::SHARD_WARM) {
                match wire::decode_vec_batch(&p) {
                    Ok(batch) => packs.extend(batch),
                    Err(_) => self.drop_relay(s),
                }
            }
        }
        packs
    }

    fn init_state(&mut self) -> Vec<(f64, Vec<f64>)> {
        // The PP bootstrap needs every client's (lᵢ, gᵢ), indexed by
        // client id — require the full tier.
        assert!(
            self.relays.iter().all(|r| r.is_some()),
            "init_state requires every relay registered"
        );
        let asked = self.ask_relays(s2c::STATE, &[]);
        assert_eq!(asked.len(), self.n_shards(), "relay lost at bootstrap");
        let mut parts: Vec<(u32, f64, Vec<f64>)> =
            Vec::with_capacity(self.n_clients);
        for s in asked {
            let p = self
                .recv_expect(s, c2s::SHARD_STATES)
                .expect("relay lost at bootstrap");
            parts.extend(
                wire::decode_id_scalar_vecs(&p).expect("states decode"),
            );
        }
        parts.sort_by_key(|&(id, _, _)| id);
        let base = self.base;
        assert!(
            parts.len() == self.n_clients
                && parts
                    .iter()
                    .enumerate()
                    .all(|(i, &(id, _, _))| id == base + i as u32),
            "init_state: incomplete client coverage"
        );
        parts.into_iter().map(|(_, l, g)| (l, g)).collect()
    }

    fn pull_state(&mut self, client: u32) -> Option<(f64, Vec<f64>)> {
        // An adopted client answers the pull over its direct channel.
        if self.adopted.iter().any(|a| a.id == client) {
            let deadline = self.deadline.or(Some(Duration::from_secs(5)));
            let a = self.adopted_mut(client)?;
            let _ = a.ch.set_read_timeout(deadline);
            if a.ch.send(s2c::STATE, &[]).is_ok() {
                if let Ok((tag, p)) = a.ch.recv() {
                    if tag == c2s::STATE {
                        if let Ok(state) = wire::decode_loss_grad(&p) {
                            return Some(state);
                        }
                    }
                }
            }
            self.retire_adopted(client);
            return None;
        }
        let s = self
            .ranges
            .iter()
            .position(|&(lo, hi)| client >= lo && client < hi)
            .unwrap_or_else(|| {
                panic!("client {client} outside every partition")
            });
        if self.relays[s].is_none() {
            return None;
        }
        let payload = {
            let mut w = crate::utils::ByteWriter::with_capacity(4);
            w.put_u32(client);
            w.into_vec()
        };
        {
            let ch = self.relays[s].as_mut()?;
            let timeout = self.deadline.or(Some(Duration::from_secs(5)));
            let _ = ch.set_read_timeout(timeout);
            if ch.send(s2c::SHARD_PULL, &payload).is_ok() {
                if let Ok((tag, p)) = ch.recv() {
                    if tag == c2s::SHARD_PULLED {
                        // Malformed payload falls through to the
                        // drop-relay path below (network input).
                        if let Ok(state) = wire::decode_shard_pulled(&p) {
                            return state;
                        }
                    }
                }
            }
        }
        self.drop_relay(s);
        None
    }

    fn ack_round(&mut self, round: u64, committed: &[u32]) {
        // Commit fan-out: one SHARD_ACK per live shard that asked for
        // acks (carrying its committed ids), one ROUND_ACK per adopted
        // staging client. Branches without staging registrants see
        // zero ack bytes, so non-failover runs meter unchanged.
        for s in 0..self.relays.len() {
            if !self.shard_ack[s] || self.relays[s].is_none() {
                continue;
            }
            let (lo, hi) = self.ranges[s];
            let part: Vec<u32> = committed
                .iter()
                .copied()
                .filter(|&c| c >= lo && c < hi)
                .filter(|&c| self.adopted.iter().all(|a| a.id != c))
                .collect();
            if part.is_empty() {
                continue;
            }
            let payload = wire::encode_shard_ack(round, &part);
            let ch = self.relays[s].as_mut().unwrap();
            if ch.send(s2c::SHARD_ACK, &payload).is_err() {
                self.drop_relay(s);
            }
        }
        let ack_ids: Vec<u32> = self
            .adopted
            .iter()
            .filter(|a| a.wants_ack && committed.contains(&a.id))
            .map(|a| a.id)
            .collect();
        let payload = wire::encode_round_ack(round);
        for id in ack_ids {
            let Some(a) = self.adopted_mut(id) else { continue };
            if a.ch.send(s2c::ROUND_ACK, &payload).is_err() {
                self.retire_adopted(id);
            }
        }
    }

    fn resolve_staged(&mut self, client: u32, last_commit: Option<u64>) {
        // Route the rejoin watermark to wherever the client is served
        // now: directly if adopted, down its shard's tier otherwise.
        if self.adopted.iter().any(|a| a.id == client) {
            let payload = wire::encode_resync(last_commit);
            let Some(a) = self.adopted_mut(client) else { return };
            if a.ch.send(s2c::RESYNC, &payload).is_err() {
                self.retire_adopted(client);
            }
            return;
        }
        let Some(s) = self
            .ranges
            .iter()
            .position(|&(lo, hi)| client >= lo && client < hi)
        else {
            return;
        };
        if !self.shard_ack[s] {
            return; // no staging registrants down that branch
        }
        let payload = wire::encode_shard_resync(client, last_commit);
        if let Some(ch) = self.relays[s].as_mut() {
            if ch.send(s2c::RESYNC, &payload).is_err() {
                self.drop_relay(s);
            }
        }
    }

    fn pull_h_packed(&mut self) -> Option<Vec<Vec<f64>>> {
        // Exact Hᵢ resync: every client of the tier must answer, in
        // global id order — a single hole (dead id, severed shard,
        // short batch) degrades to `None` and the engine falls back
        // to the approximate resync.
        let mut slots: Vec<Option<Vec<f64>>> = vec![None; self.n_clients];
        let asked = self.ask_relays(s2c::PULL_H, &[]);
        let adopted = self.ask_adopted(s2c::PULL_H, &[]);
        for s in asked {
            let (lo, hi) = self.ranges[s];
            let Some(p) = self.recv_expect(s, c2s::SHARD_WARM) else {
                continue;
            };
            let Ok(packs) = wire::decode_vec_batch(&p) else {
                self.drop_relay(s);
                continue;
            };
            if packs.len() != (hi - lo) as usize {
                continue; // partition incomplete (adoptees answer
                          // directly; holes fail the pull below)
            }
            for (i, pack) in packs.into_iter().enumerate() {
                slots[(lo - self.base) as usize + i] = Some(pack);
            }
        }
        for ci in adopted {
            if let Some(p) = self.recv_adopted_expect(ci, c2s::WARM) {
                match wire::decode_vec(&p) {
                    Ok(pack) => {
                        slots[(ci - self.base) as usize] = Some(pack)
                    }
                    Err(_) => self.retire_adopted(ci),
                }
            }
        }
        slots.into_iter().collect()
    }

    fn supports_shard_kill(&self) -> bool {
        true
    }

    fn kill_shard(&mut self, shard: u32) {
        // Scripted failover injection: sever the upward channel to
        // this relay abruptly. The relay observes EOF, dies without a
        // downward SHUTDOWN, and its clients fail over; adoption at
        // the next `prepare_round` heals the partition.
        let s = shard as usize;
        assert!(
            s < self.relays.len(),
            "killrelay names shard {shard} but the tier has {} shards",
            self.relays.len()
        );
        self.drop_relay(s);
    }

    fn shard_ranges(&self) -> Option<Vec<(u32, u32)>> {
        Some(self.ranges.clone())
    }

    fn transport_bytes(&self) -> Option<(u64, u64)> {
        let up = self.retired_bytes.0
            + self
                .relays
                .iter()
                .flatten()
                .map(|c| c.bytes_received)
                .sum::<u64>()
            + self
                .adopted
                .iter()
                .map(|a| a.ch.bytes_received)
                .sum::<u64>();
        let down = self.retired_bytes.1
            + self
                .relays
                .iter()
                .flatten()
                .map(|c| c.bytes_sent)
                .sum::<u64>()
            + self.adopted.iter().map(|a| a.ch.bytes_sent).sum::<u64>();
        Some((up, down))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relay_slack_validation() {
        // Zero is rejected with a clear message (mirroring
        // RoundPolicy::validate's zero-deadline rule); positive values
        // parse to the exact duration.
        let err = relay_slack_from_ms(0).unwrap_err().to_string();
        assert!(err.contains("--relay-slack-ms"), "{err}");
        assert!(err.contains("2000"), "{err}");
        assert_eq!(
            relay_slack_from_ms(1).unwrap(),
            Duration::from_millis(1)
        );
        assert_eq!(
            relay_slack_from_ms(7500).unwrap(),
            Duration::from_millis(7500)
        );
        assert_eq!(DEFAULT_RELAY_SLACK, Duration::from_millis(2000));
    }

    #[test]
    fn adopt_grace_validation() {
        let err = adopt_grace_from_ms(0).unwrap_err().to_string();
        assert!(err.contains("--adopt-grace-ms"), "{err}");
        assert!(err.contains("2000"), "{err}");
        assert_eq!(
            adopt_grace_from_ms(250).unwrap(),
            Duration::from_millis(250)
        );
        assert_eq!(DEFAULT_ADOPT_GRACE, Duration::from_millis(2000));
    }
}

//! API-compatible stub for the PJRT runtime, compiled when the `xla`
//! cargo feature is off (the default). Keeps every call site building —
//! `PjrtRuntime::load` fails with a descriptive error, which callers
//! already handle as "artifacts unavailable" (the CLI prints a notice,
//! the PJRT integration tests skip).

use anyhow::{bail, Result};

use crate::data::ClientShard;
use crate::linalg::Mat;
use crate::oracle::Oracle;

/// One AOT-compiled shape from `artifacts/manifest.tsv`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeEntry {
    pub name: String,
    /// Problem dimension d (including intercept) the shape was built for.
    pub d_raw: usize,
    /// Max per-client samples the shape accommodates.
    pub n_raw: usize,
    pub d_pad: usize,
    pub n_pad: usize,
    pub oracle_file: String,
    pub grad_file: String,
}

/// Stub PJRT client: construction always fails.
pub struct PjrtRuntime {
    pub entries: Vec<ShapeEntry>,
}

impl PjrtRuntime {
    /// Always fails: PJRT support is not compiled into this build.
    pub fn load(dir: &str) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: this binary was built without the \
             `xla` cargo feature (artifacts dir requested: {dir}). \
             Rebuild with `--features xla` (and the xla dependency) to \
             use the AOT JAX/Pallas oracle."
        )
    }

    /// Smallest artifact shape that fits a (d, n_i) client problem.
    pub fn find_shape(&self, d: usize, n_i: usize) -> Option<&ShapeEntry> {
        self.entries
            .iter()
            .filter(|e| e.d_pad >= d && e.n_pad >= n_i)
            .min_by_key(|e| (e.d_pad, e.n_pad))
    }

    /// Always fails (a stub runtime cannot be constructed anyway).
    pub fn oracle_for_shard(
        &self,
        _shard: &ClientShard,
        _lam: f64,
    ) -> Result<PjrtOracle> {
        bail!("PJRT runtime unavailable (built without the `xla` feature)")
    }
}

/// Uninstantiable stand-in for the PJRT-backed oracle.
pub struct PjrtOracle {
    _private: (),
}

impl Oracle for PjrtOracle {
    fn dim(&self) -> usize {
        unreachable!("stub PjrtOracle cannot be constructed")
    }

    fn loss(&mut self, _x: &[f64]) -> f64 {
        unreachable!("stub PjrtOracle cannot be constructed")
    }

    fn loss_grad(&mut self, _x: &[f64], _g: &mut [f64]) -> f64 {
        unreachable!("stub PjrtOracle cannot be constructed")
    }

    fn loss_grad_hessian(
        &mut self,
        _x: &[f64],
        _g: &mut [f64],
        _h: &mut Mat,
    ) -> f64 {
        unreachable!("stub PjrtOracle cannot be constructed")
    }
}

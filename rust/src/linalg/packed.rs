//! Packed upper-triangle representation of symmetric d×d matrices.
//!
//! FedNL compresses the *difference of symmetric matrices*
//! `∇²f_i(xᵏ) − H_iᵏ`; all compressors therefore operate on the packed
//! upper triangle (length d(d+1)/2), exactly as the paper's RandK/TopK
//! act on "elements from the upper triangular part" (Appendix C.1).
//! Index tables are precomputed once and reused every round (§5.11 v31).

use super::matrix::Mat;
use super::simd;

// (see tests: packed_idx is validated against full enumeration)

/// Number of packed entries for a d×d symmetric matrix.
#[inline]
pub const fn packed_len(d: usize) -> usize {
    d * (d + 1) / 2
}

/// Flat index of (i, j), i ≤ j, in row-major packed upper-triangle order.
#[inline]
pub fn packed_idx(d: usize, i: usize, j: usize) -> usize {
    debug_assert!(i <= j && j < d);
    // Row i starts after rows 0..i, whose lengths are d, d-1, ..., d-i+1.
    i * d - (i * i - i) / 2 + (j - i)
}

/// Precomputed (i, j) pair for every packed index, plus the weight used
/// in Frobenius accounting (1 for diagonal, 2 for off-diagonal).
#[derive(Debug, Clone)]
pub struct PackedUpper {
    d: usize,
    pairs: Vec<(u32, u32)>,
    /// Frobenius weight per packed index (1 diagonal, 2 off-diagonal),
    /// stored densely so energy scans vectorize (§5.11 precomputed
    /// tables + SIMD kernel layer).
    weights: Vec<f64>,
}

impl PackedUpper {
    /// Build the index table for dimension `d` (done once per client).
    pub fn new(d: usize) -> Self {
        let mut pairs = Vec::with_capacity(packed_len(d));
        let mut weights = Vec::with_capacity(packed_len(d));
        for i in 0..d {
            for j in i..d {
                pairs.push((i as u32, j as u32));
                weights.push(if i == j { 1.0 } else { 2.0 });
            }
        }
        Self { d, pairs, weights }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// (i, j) for packed index `k`.
    #[inline]
    pub fn pair(&self, k: usize) -> (usize, usize) {
        let (i, j) = self.pairs[k];
        (i as usize, j as usize)
    }

    /// Frobenius weight of packed index `k` (1 diagonal, 2 off-diagonal).
    #[inline]
    pub fn weight(&self, k: usize) -> f64 {
        self.weights[k]
    }

    /// Dense per-index Frobenius weights (length = `len()`), for
    /// vectorized energy scans.
    #[inline]
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Extract `mat`'s upper triangle into `out` (len = packed_len(d)).
    pub fn pack(&self, mat: &Mat, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.len());
        let d = self.d;
        let mut k = 0;
        for i in 0..d {
            let row = &mat.row(i)[i..];
            out[k..k + row.len()].copy_from_slice(row);
            k += row.len();
        }
    }

    /// Scatter packed values into a full symmetric matrix.
    pub fn unpack(&self, packed: &[f64], mat: &mut Mat) {
        debug_assert_eq!(packed.len(), self.len());
        let d = self.d;
        let mut k = 0;
        for i in 0..d {
            for j in i..d {
                mat.set(i, j, packed[k]);
                mat.set(j, i, packed[k]);
                k += 1;
            }
        }
    }

    /// Apply a sparse symmetric update `mat += α · Σ values[t] e_{i,j}`
    /// at the given packed indices — the master-side Line 10 update.
    /// Sparse (skips untouched entries, §5.6): cost O(k) not O(d²).
    pub fn apply_sparse(
        &self,
        mat: &mut Mat,
        alpha: f64,
        indices: &[u32],
        values: &[f64],
    ) {
        debug_assert_eq!(indices.len(), values.len());
        for (&k, &v) in indices.iter().zip(values) {
            let (i, j) = self.pair(k as usize);
            mat.add_at(i, j, alpha * v);
            if i != j {
                mat.add_at(j, i, alpha * v);
            }
        }
    }

    /// y = M·x where M is the symmetric matrix with packed upper
    /// triangle `packed` (used by FedNL-PP's Hessian-corrected local
    /// gradient gᵢ = (Hᵢ + lᵢI)wᵢ − ∇fᵢ without densifying Hᵢ).
    /// Each packed row contributes one contiguous dot (row · x[i..]) and
    /// one contiguous AXPY (the mirrored lower part) — both dispatched.
    pub fn matvec_packed(&self, packed: &[f64], x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(packed.len(), self.len());
        let d = self.d;
        debug_assert!(x.len() == d && y.len() == d);
        for yi in y.iter_mut() {
            *yi = 0.0;
        }
        let mut k = 0;
        for i in 0..d {
            let len = d - i;
            let row = &packed[k..k + len];
            y[i] += simd::dot(row, &x[i..]);
            simd::axpy(x[i], &row[1..], &mut y[i + 1..]);
            k += len;
        }
    }

    /// Frobenius-squared of the symmetric matrix whose packed form is
    /// `packed`: diagonal entries count once, off-diagonal twice
    /// (vectorized weighted-norm scan over the precomputed weights).
    pub fn frobenius_sq_packed(&self, packed: &[f64]) -> f64 {
        debug_assert_eq!(packed.len(), self.len());
        simd::weighted_norm2_sq(&self.weights, packed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn idx_matches_enumeration() {
        for d in 1..12 {
            let pu = PackedUpper::new(d);
            for k in 0..pu.len() {
                let (i, j) = pu.pair(k);
                assert_eq!(packed_idx(d, i, j), k);
            }
            assert_eq!(pu.len(), packed_len(d));
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let d = 7;
        let mut rng = Pcg64::seed_from_u64(1);
        let mut m = Mat::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                let v = rng.next_gaussian();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let pu = PackedUpper::new(d);
        let mut packed = vec![0.0; pu.len()];
        pu.pack(&m, &mut packed);
        let mut back = Mat::zeros(d, d);
        pu.unpack(&packed, &mut back);
        assert!(m.max_abs_diff(&back) < 1e-15);
    }

    #[test]
    fn frobenius_packed_matches_dense() {
        let d = 9;
        let mut rng = Pcg64::seed_from_u64(2);
        let mut m = Mat::zeros(d, d);
        for i in 0..d {
            for j in i..d {
                let v = rng.next_gaussian();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let pu = PackedUpper::new(d);
        let mut packed = vec![0.0; pu.len()];
        pu.pack(&m, &mut packed);
        let f1 = pu.frobenius_sq_packed(&packed);
        let f2 = m.frobenius_sq();
        assert!((f1 - f2).abs() < 1e-10 * f2.max(1.0));
    }

    #[test]
    fn apply_sparse_symmetric() {
        let d = 5;
        let pu = PackedUpper::new(d);
        let mut m = Mat::zeros(d, d);
        let idx = [packed_idx(d, 0, 0) as u32, packed_idx(d, 1, 3) as u32];
        pu.apply_sparse(&mut m, 2.0, &idx, &[1.0, 5.0]);
        assert_eq!(m.get(0, 0), 2.0);
        assert_eq!(m.get(1, 3), 10.0);
        assert_eq!(m.get(3, 1), 10.0);
        assert!(m.is_symmetric(0.0));
    }
}
